"""Packed genotype residency (DESIGN.md §17): device-side decode exactness,
the shared packed-slab cache, staging negotiation, and end-to-end bitwise
identity of packed vs dense staging across every engine."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import GridSpec, IOSpec, LmmSpec, Study, TsvWriter
from repro.core.engines import resolve_genotype_staging
from repro.core.grm import stream_grm
from repro.io import NumpyGenotypes, open_genotypes, synth
from repro.io.packed_cache import PackedSlabCache
from repro.io.plink import PlinkBed, pack_dosages, write_plink
from repro.kernels.gwas_dot import ops as kops

TSVS = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")


@pytest.fixture(scope="module")
def ragged_cohort():
    # N % 4 == 3 so every packed row has a partial tail byte.
    return synth.make_cohort(
        n_samples=403, n_markers=300, n_traits=8, n_causal=6,
        missing_rate=0.05, seed=11,
    )


@pytest.fixture(scope="module")
def ragged_beds(ragged_cohort, tmp_path_factory):
    stem = str(tmp_path_factory.mktemp("packed") / "toy")
    return synth.write_split_plink(ragged_cohort, stem, n_shards=3)


# ------------------------------------------------------------ device decode


def test_device_decode_matches_host_lut(ragged_cohort, ragged_beds):
    src = PlinkBed(ragged_beds[0])
    packed = src.read_packed(0, src.n_markers)
    host = src.read_dosages(0, src.n_markers).astype(np.float32)
    dev = np.asarray(kops.decode_packed_device(packed, n_samples=src.n_samples))
    assert dev.dtype == np.float32
    np.testing.assert_array_equal(dev, host)


@pytest.mark.parametrize("n_samples", [1, 2, 3, 4, 5, 7, 8, 403])
def test_device_decode_ragged_tail(n_samples):
    rng = np.random.default_rng(n_samples)
    d = rng.choice(np.int8([-9, 0, 1, 2]), size=(6, n_samples))
    packed = pack_dosages(d)
    dev = np.asarray(kops.decode_packed_device(packed, n_samples=n_samples))
    np.testing.assert_array_equal(dev, d.astype(np.float32))


def test_device_repack_matches_host_tile_pack(ragged_beds):
    src = PlinkBed(ragged_beds[1])
    m, n = src.n_markers, src.n_samples
    packed = src.read_packed(0, m)
    codes = kops.unpack_plink_to_codes(packed, n)
    host = kops.pack_tiled(codes, 128)
    dev = np.asarray(
        kops.repack_plink_tiled_device(packed, n_samples=n, block_n=128, block_m=64)
    )
    # Real rows are byte-identical; device pad rows use the all-missing byte
    # (every slot 0b01) where the host pads with 0x01 — both standardize to
    # exactly 0 under padded mean/inv_std of 0, and rows are independent.
    assert dev.shape[0] == m + (-m) % 64
    np.testing.assert_array_equal(dev[:m], host[:m])


def test_marker_stats_from_packed_bitwise(ragged_beds):
    src = PlinkBed(ragged_beds[2])
    packed = src.read_packed(0, src.n_markers)
    codes = kops.unpack_plink_to_codes(packed, src.n_samples)
    want = kops.marker_stats_from_codes(codes)
    got = kops.marker_stats_from_packed(packed, src.n_samples)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(g, w)
        assert g.dtype == w.dtype


def test_marker_stats_from_packed_edge_markers():
    # all-missing, monomorphic, and pad-slot contamination candidates
    d = np.array(
        [
            [-9, -9, -9, -9, -9],   # no present samples -> invalid
            [2, 2, 2, 2, 2],        # monomorphic -> zero variance -> invalid
            [0, 1, 2, -9, 1],
            [1, 1, 1, 1, 0],
        ],
        np.int8,
    )
    packed = pack_dosages(d)
    mean, inv, valid = kops.marker_stats_from_packed(packed, d.shape[1])
    w_mean, w_inv, w_valid = kops.marker_stats_from_codes(
        kops.unpack_plink_to_codes(packed, d.shape[1])
    )
    np.testing.assert_array_equal(mean, w_mean)
    np.testing.assert_array_equal(inv, w_inv)
    np.testing.assert_array_equal(valid, w_valid)
    assert not valid[0] and not valid[1] and valid[2] and valid[3]


# -------------------------------------------------------------- slab cache


class _CountingBed(PlinkBed):
    def __post_init__(self):
        super().__post_init__()
        self.reads = 0

    def read_packed(self, lo, hi):
        self.reads += 1
        return super().read_packed(lo, hi)


def test_cache_hits_and_key_stability(ragged_beds):
    cache = PackedSlabCache(capacity_bytes=1 << 20)
    a = _CountingBed(ragged_beds[0])
    s1 = cache.read(a, 0, 10)
    s2 = cache.read(a, 0, 10)
    assert a.reads == 1 and s1 is s2 and not s1.flags.writeable
    # A different instance over the same fileset shares the entry (serve's
    # per-request sources, resumed scans).
    b = _CountingBed(ragged_beds[0])
    s3 = cache.read(b, 0, 10)
    assert b.reads == 0 and s3 is s1
    st = cache.stats()
    assert st["hits"] == 2 and st["misses"] == 1


def test_cache_lru_eviction(ragged_beds):
    src = PlinkBed(ragged_beds[0])
    row = (src.n_samples + 3) // 4
    cache = PackedSlabCache(capacity_bytes=row * 25)  # fits two 10-marker slabs
    cache.read(src, 0, 10)
    cache.read(src, 10, 20)
    cache.read(src, 20, 30)   # evicts [0, 10)
    assert cache.stats()["evictions"] == 1
    counting = _CountingBed(ragged_beds[0])
    cache.read(counting, 0, 10)
    assert counting.reads == 1  # was evicted -> re-read
    cache.read(counting, 20, 30)
    assert counting.reads == 1  # still resident


def test_cache_bypasses_unkeyed_sources(ragged_cohort, tmp_path):
    path = str(tmp_path / "g.npy")
    np.save(path, ragged_cohort.dosages)
    src = NumpyGenotypes(path)

    class Unkeyed:
        def read_packed(self, lo, hi):
            return src.read_packed(lo, hi)

    cache = PackedSlabCache()
    cache.read(Unkeyed(), 0, 5)
    assert cache.stats()["bypasses"] == 1 and cache.stats()["entries"] == 0


# -------------------------------------------------------- staging negotiation


def test_resolution_matrix(ragged_cohort, ragged_beds, tmp_path):
    plink_src = PlinkBed(ragged_beds[0])
    multi = open_genotypes(",".join(ragged_beds))
    path = str(tmp_path / "g.npy")
    np.save(path, ragged_cohort.dosages)
    numpy_src = NumpyGenotypes(path)

    assert resolve_genotype_staging("auto", plink_src) == "packed"
    assert resolve_genotype_staging("auto", multi) == "packed"
    assert resolve_genotype_staging("dense", plink_src) == "dense"
    assert resolve_genotype_staging("auto", numpy_src) == "dense"
    # blockers force the decoded path under auto ...
    assert resolve_genotype_staging("auto", plink_src, excluded_samples=3) == "dense"
    assert resolve_genotype_staging("auto", plink_src, mesh=object()) == "dense"
    # ... and refuse an explicit packed request loudly
    with pytest.raises(ValueError, match="no native 2-bit layout"):
        resolve_genotype_staging("packed", numpy_src)
    with pytest.raises(ValueError, match="exclusion"):
        resolve_genotype_staging("packed", plink_src, excluded_samples=3)
    with pytest.raises(ValueError, match="unknown genotype staging"):
        resolve_genotype_staging("bogus", plink_src)


def test_iospec_validates_staging():
    with pytest.raises(ValueError, match="genotype_staging"):
        IOSpec(genotype_staging="nope").validate()
    IOSpec(genotype_staging="packed").validate()


def test_staging_never_enters_fingerprint():
    from repro.api.specs import ScanConfig

    a = ScanConfig(genotype_staging="packed", packed_cache_mb=64)
    b = ScanConfig(genotype_staging="dense")
    assert a.fingerprint_payload() == b.fingerprint_payload()


# ------------------------------------------------- end-to-end bitwise identity


def _scan(source, cohort, out, *, staging, engine="dense", devices=1, **plan_kw):
    study = Study.from_arrays(source, cohort.phenotypes, cohort.covariates)
    plan_kw.setdefault("grid", GridSpec(batch_markers=128, trait_block=5))
    if devices != 1:
        from repro.api import ExecSpec

        plan_kw["executor"] = ExecSpec(devices=devices)
    plan = study.plan(io=IOSpec(genotype_staging=staging), engine=engine,
                      hit_threshold_nlp=2.0, **plan_kw)
    session = plan.run()
    session.stream_to(TsvWriter(str(out)))
    return plan, session


def _read(out):
    return {f: (out / f).read_text() for f in TSVS}


@pytest.mark.parametrize(
    "engine,extra",
    [
        ("dense", {}),
        ("fused", {}),
        ("lmm", {"lmm": LmmSpec(loco=True, grm_batch_markers=128)}),
    ],
)
def test_packed_vs_dense_bitwise(engine, extra, ragged_cohort, ragged_beds, tmp_path):
    """Ragged N (403), missing codes, multi-file shard boundaries: packed
    staging emits byte-identical TSVs for every engine."""
    src = open_genotypes(",".join(ragged_beds))
    plan_d, _ = _scan(src, ragged_cohort, tmp_path / "dense",
                      staging="dense", engine=engine, **extra)
    plan_p, sess_p = _scan(src, ragged_cohort, tmp_path / "packed",
                           staging="packed", engine=engine, **extra)
    assert plan_d.prepare().ctx.genotype_staging == "dense"
    assert plan_p.prepare().ctx.genotype_staging == "packed"
    assert _read(tmp_path / "packed") == _read(tmp_path / "dense")
    m = sess_p.metrics.summary()
    assert m["h2d_bytes"] > 0
    # ceil(403/4)=101 packed bytes vs 4*403=1612 dense bytes per marker
    # (plus small stat vectors on the fused path) — well past the 8x floor.
    assert m["h2d_bytes_per_marker"] < 1612 / 8


def test_numpy_source_auto_falls_back_dense(ragged_cohort, tmp_path):
    np.save(tmp_path / "g.npy", ragged_cohort.dosages)
    src = NumpyGenotypes(str(tmp_path / "g.npy"))
    plan, _ = _scan(src, ragged_cohort, tmp_path / "np_auto", staging="auto")
    assert plan.prepare().ctx.genotype_staging == "dense"
    with pytest.raises(ValueError, match="packed.*unavailable"):
        _scan(src, ragged_cohort, tmp_path / "np_packed", staging="packed")


def test_h2d_bytes_accounting_ratio(ragged_cohort, ragged_beds, tmp_path):
    src = open_genotypes(",".join(ragged_beds))
    _, dense = _scan(src, ragged_cohort, tmp_path / "d", staging="dense")
    _, packed = _scan(src, ragged_cohort, tmp_path / "p", staging="packed")
    bd = dense.metrics.summary()["h2d_bytes_per_marker"]
    bp = packed.metrics.summary()["h2d_bytes_per_marker"]
    assert bd / bp >= 8.0


# ----------------------------------------------------------------- GRM path


@pytest.mark.parametrize("method", ["std", "centered"])
def test_grm_packed_bitwise(method, ragged_beds):
    multi = open_genotypes(",".join(ragged_beds))
    dense = stream_grm(multi, batch_markers=128, method=method, staging="dense")
    packed = stream_grm(multi, batch_markers=128, method=method, staging="packed")
    np.testing.assert_array_equal(packed.shard_sums, dense.shard_sums)
    np.testing.assert_array_equal(packed.shard_norms, dense.shard_norms)
    np.testing.assert_array_equal(packed.full(), dense.full())


def test_grm_keep_mask_falls_back(ragged_beds):
    src = PlinkBed(ragged_beds[0])
    keep = np.ones(src.n_samples, bool)
    keep[:5] = False
    # auto + excluding mask -> decoded path, same numbers as before this PR
    g = stream_grm(src, keep=keep, batch_markers=128, staging="auto")
    assert g.n_samples == src.n_samples - 5
    with pytest.raises(ValueError, match="exclusion"):
        stream_grm(src, keep=keep, batch_markers=128, staging="packed")
    # an all-true mask never subsets, so packed stays eligible
    g2 = stream_grm(src, keep=np.ones(src.n_samples, bool),
                    batch_markers=128, staging="packed")
    assert g2.n_samples == src.n_samples


# ------------------------------------------------- resume / replay reuse


def test_resume_hits_packed_cache(ragged_cohort, ragged_beds, tmp_path):
    """A resumed scan re-preps only pending batches, and those reads hit the
    shared slab cache instead of the disk (satellite: replay/resume should
    not re-prep)."""
    from repro.io.packed_cache import default_cache

    default_cache().clear()
    src = _CountingBed(ragged_beds[0])
    cohort_slice = ragged_cohort
    study = Study.from_arrays(src, cohort_slice.phenotypes, cohort_slice.covariates)
    ck = tmp_path / "ck"
    grid = GridSpec(batch_markers=64, trait_block=5)

    plan = study.plan(grid=grid, io=IOSpec(genotype_staging="packed"),
                      checkpoint_dir=str(ck), hit_threshold_nlp=2.0)
    session = plan.run()
    session.stream_to(TsvWriter(str(tmp_path / "full")))
    first_reads = src.reads
    assert first_reads > 0

    # Cut one mid-grid cell from the manifest and resume: only that batch
    # re-preps, and its slab comes from the cache (no new disk read).
    mpath = ck / "manifest.json"
    mani = json.loads(mpath.read_text())
    # trait_block=5 rounds up past n_traits, so cell keys are bare batch ids
    assert "1" in mani["completed"]
    mani["completed"].pop("1")
    mpath.write_text(json.dumps(mani))

    before = default_cache().stats()["hits"]
    plan2 = study.plan(grid=grid, io=IOSpec(genotype_staging="packed"),
                       checkpoint_dir=str(ck), hit_threshold_nlp=2.0)
    session2 = plan2.run()
    session2.stream_to(TsvWriter(str(tmp_path / "resumed")))
    assert src.reads == first_reads           # zero new disk reads
    assert default_cache().stats()["hits"] > before
    assert _read(tmp_path / "resumed") == _read(tmp_path / "full")


# ------------------------------------------- multi-device (4 fake devices)


_CHILD = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, tempfile
    import os.path as osp
    from repro.api import ExecSpec, GridSpec, IOSpec, Study, TsvWriter
    from repro.io import open_genotypes, synth

    co = synth.make_cohort(n_samples=203, n_markers=320, n_traits=10,
                           n_causal=4, missing_rate=0.04, seed=9)
    d = tempfile.mkdtemp()
    beds = synth.write_split_plink(co, osp.join(d, "toy"), n_shards=3)
    src = open_genotypes(",".join(beds))
    study = Study.from_arrays(src, co.phenotypes, co.covariates)
    grid = GridSpec(batch_markers=96, block_m=64, block_n=128, trait_block=5)
    FILES = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")

    def scan(tag, staging, devices, engine="dense"):
        plan = study.plan(
            engine=engine, grid=grid, hit_threshold_nlp=2.0,
            io=IOSpec(genotype_staging=staging),
            executor=ExecSpec(devices=devices),
        )
        session = plan.run()
        out = osp.join(d, tag)
        session.stream_to(TsvWriter(out))
        files = {f: open(osp.join(out, f)).read() for f in FILES}
        return files, session

    out = {}
    for engine in ("dense", "fused"):
        ref, _ = scan(f"{engine}_serial_dense", "dense", 1, engine)
        pk1, s1 = scan(f"{engine}_serial_packed", "packed", 1, engine)
        pk4, s4 = scan(f"{engine}_md_packed", "packed", 4, engine)
        out[f"{engine}_serial_identical"] = pk1 == ref
        out[f"{engine}_md_identical"] = pk4 == ref
        out[f"{engine}_md_devices"] = len(
            s4.metrics.summary()["per_device"]
        )
        out[f"{engine}_md_h2d_per_marker"] = s4.metrics.summary()[
            "h2d_bytes_per_marker"
        ]
    print(json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def packed_md_results(tmp_path_factory):
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env = dict(os.environ, PYTHONPATH=src)
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        timeout=900, env=env, cwd=str(tmp_path_factory.mktemp("packed_md")),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("engine", ["dense", "fused"])
def test_multi_device_packed_bitwise(packed_md_results, engine):
    assert packed_md_results[f"{engine}_serial_identical"] is True
    assert packed_md_results[f"{engine}_md_identical"] is True
    assert packed_md_results[f"{engine}_md_devices"] >= 2
    # 203 samples: ceil(203/4)=51 packed vs 812 dense f32 bytes/marker
    assert packed_md_results[f"{engine}_md_h2d_per_marker"] < 812 / 4
