"""Deprecated-shim contract: ``GenomeScan.run()`` on the N=500 ragged
3-shard fileset must reproduce goldens captured on the PRE-redesign driver
(the monolithic ``GenomeScan.run`` loop, commit 9c36724), for all three
engines over a blocked 2-D grid.

The shim now binds a Study, prepares a plan, and folds ``ScanSession``
events through the historical sinks — these goldens pin that the redesign
changed *where the loop lives*, not a single statistic.  Regenerate only if
the synthesis recipe or the statistics change deliberately; any other drift
is exactly the bug this guard exists to catch.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.screening import GenomeScan, ScanConfig
from repro.io import open_genotypes, synth

# Captured on the pre-redesign tree (see module docstring): engine ->
# summary of hits/best/QC/lambda on the fixture below.
GOLDEN = {
    "dense": {
        "best_nlp": [23.9688, 25.2223, 28.0233, 20.8547, 24.7267, 24.3832,
                     22.756, 29.8587, 5.2958, 1.7119, 2.5333, 2.6408,
                     3.0878, 2.4077, 2.6485, 2.7077],
        "best_marker": [116, 278, 263, 155, 122, 86, 17, 133, 257, 290,
                        189, 99, 253, 156, 299, 89],
        "n_hits": 10,
        "hits_marker_sum": 1493,
        "hits_trait_sum": 40,
        "hits_nlp_sum": 209.332,
        "maf_sum": 82.9204,
        "n_valid": 300,
        "lambda_gc": 1.3209,
        "dof": 498,
    },
    "fused": {
        "best_nlp": [23.9688, 25.2223, 28.0233, 20.8547, 24.7267, 24.3832,
                     22.756, 29.8587, 5.2958, 1.7119, 2.5333, 2.6408,
                     3.0878, 2.4077, 2.6485, 2.7077],
        "best_marker": [116, 278, 263, 155, 122, 86, 17, 133, 257, 290,
                        189, 99, 253, 156, 299, 89],
        "n_hits": 10,
        "hits_marker_sum": 1493,
        "hits_trait_sum": 40,
        "hits_nlp_sum": 209.332,
        "maf_sum": 82.9204,
        "n_valid": 300,
        "lambda_gc": 1.3209,
        "dof": 498,
    },
    "lmm": {
        "best_nlp": [23.65, 23.8221, 30.0065, 20.3694, 26.0932, 22.9383,
                     22.8679, 27.3632, 6.4209, 2.4792, 2.9346, 3.0886,
                     3.5512, 2.6117, 3.0704, 2.8654],
        "best_marker": [116, 278, 263, 155, 122, 86, 17, 133, 257, 290,
                        215, 99, 253, 123, 299, 89],
        "n_hits": 10,
        "hits_marker_sum": 1493,
        "hits_trait_sum": 40,
        "hits_nlp_sum": 208.262,
        "maf_sum": 82.9204,
        "n_valid": 300,
        "lambda_gc": 1.3095,
        "dof": 496,
    },
}

ENGINE_EXTRAS = {
    "dense": {},
    "fused": {},
    "lmm": {"lmm_delta": 1.0, "loco": True},
}


@pytest.fixture(scope="module")
def ragged_source(tmp_path_factory):
    cohort = synth.make_cohort(
        n_samples=500, n_markers=300, n_traits=16, n_covariates=2,
        n_causal=8, effect_size=0.5, missing_rate=0.01, seed=97,
    )
    stem = str(tmp_path_factory.mktemp("shim_golden") / "cohort")
    beds = synth.write_split_plink(cohort, stem, n_shards=3)
    return cohort, open_genotypes(",".join(beds))


@pytest.mark.parametrize("engine", ["dense", "fused", "lmm"])
def test_shim_reproduces_pre_redesign_goldens(ragged_source, engine):
    cohort, src = ragged_source
    assert src.n_shards == 3
    cfg = ScanConfig(
        batch_markers=64, trait_block=8, engine=engine,
        hit_threshold_nlp=4.0, block_m=32, block_n=128, block_p=8,
        **ENGINE_EXTRAS[engine],
    )
    res = GenomeScan(src, cohort.phenotypes, cohort.covariates, config=cfg).run()
    order = np.lexsort((res.hits[:, 1], res.hits[:, 0]))
    hits, hstats = res.hits[order], res.hit_stats[order]
    g = GOLDEN[engine]
    np.testing.assert_allclose(res.best_nlp, g["best_nlp"], atol=1e-3)
    np.testing.assert_array_equal(res.best_marker, g["best_marker"])
    assert len(hits) == g["n_hits"]
    assert int(hits[:, 0].sum()) == g["hits_marker_sum"]
    assert int(hits[:, 1].sum()) == g["hits_trait_sum"]
    assert float(hstats[:, 2].sum()) == pytest.approx(g["hits_nlp_sum"], abs=1e-2)
    assert float(res.maf.sum()) == pytest.approx(g["maf_sum"], abs=1e-3)
    assert int(res.valid.sum()) == g["n_valid"]
    assert res.lambda_gc == pytest.approx(g["lambda_gc"], abs=1e-3)
    assert res.dof == g["dof"]


@pytest.mark.parametrize("engine", ["dense", "fused", "lmm"])
def test_streamed_writers_match_shim_on_ragged_fileset(ragged_source, engine, tmp_path):
    """The same fileset through the API's streaming path: writer outputs
    must agree with the (golden-pinned) shim result cell for cell."""
    from repro.api import Study, GridSpec, LmmSpec, TsvWriter

    cohort, src = ragged_source
    study = Study.from_arrays(src, cohort.phenotypes, cohort.covariates)
    session = study.plan(
        engine=engine,
        grid=GridSpec(batch_markers=64, trait_block=8, block_m=32,
                      block_n=128, block_p=8),
        lmm=LmmSpec(delta=1.0, loco=True) if engine == "lmm" else None,
        hit_threshold_nlp=4.0,
    ).run()
    out = tmp_path / engine
    summary = session.stream_to(TsvWriter(str(out)))
    g = GOLDEN[engine]
    assert summary["hits"] == g["n_hits"]
    assert summary["lambda_gc"] == pytest.approx(g["lambda_gc"], abs=1e-3)
    best_lines = (out / "per_trait_best.tsv").read_text().strip().splitlines()[1:]
    got_best = [float(l.split("\t")[2]) for l in best_lines]
    np.testing.assert_allclose(got_best, g["best_nlp"], atol=2e-3)
