"""The shared-fs scheduler backend (DESIGN.md §14).

The contract under test: N independent processes pointed at one checkpoint
directory drain one scan grid through the filesystem lease table, and every
one of them emits outputs byte-identical to a serial single-process scan —
under any kill/join sequence.  Units cover the lease/steal/expiry protocol
and the manifest's read-merge-write; subprocesses cover two live hosts and
a SIGKILL'd host whose tail a survivor reclaims.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.runtime.checkpoint import ScanCheckpoint, config_fingerprint
from repro.runtime.workqueue import (
    FsWorkQueue,
    WorkQueue,
    available_backends,
    get_backend,
)

FILES = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")


def _read_out(d):
    return {f: open(os.path.join(d, f), "rb").read() for f in FILES}


# ---------------------------------------------------------------- registry


def test_backend_registry():
    assert available_backends() == ("shared-fs", "threads")
    assert get_backend("threads") is WorkQueue
    assert get_backend("shared-fs") is FsWorkQueue
    with pytest.raises(ValueError, match="shared-fs"):
        get_backend("carrier-pigeon")


# ------------------------------------------------------- lease-table units


def _drain(q, worker="w"):
    got = []
    while (i := q.claim(worker, block=False)) is not None:
        got.append(i)
        q.complete(worker, i)
    return got


def test_fs_queue_single_host_lifecycle(tmp_path):
    q = FsWorkQueue(5, keys=[f"k{i}" for i in range(5)], lease_size=2,
                    root=str(tmp_path), host_id="A", lease_ttl=60.0)
    assert sorted(_drain(q)) == list(range(5))
    assert q.remaining() == 0
    # every lease file ended in the done state
    for i in range(5):
        rec = json.load(open(tmp_path / f"lease_k{i}.json"))
        assert rec["state"] == "done" and rec["host"] == "A"
    # a fresh joiner sees a finished grid, not work
    late = FsWorkQueue(5, keys=[f"k{i}" for i in range(5)], lease_size=2,
                       root=str(tmp_path), host_id="B", lease_ttl=60.0)
    assert late.claim("w", block=False) is None
    assert late.remaining() == 0
    q.stop(); late.stop()


def test_fs_queue_two_hosts_partition_items(tmp_path):
    keys = [f"b{i:06d}" for i in range(12)]
    a = FsWorkQueue(12, keys=keys, lease_size=3, root=str(tmp_path),
                    host_id="A", lease_ttl=60.0)
    b = FsWorkQueue(12, keys=keys, lease_size=3, root=str(tmp_path),
                    host_id="B", lease_ttl=60.0)
    got_a, got_b = [], []
    while True:
        ia = a.claim("w", block=False)
        ib = b.claim("w", block=False)
        if ia is None and ib is None:
            break
        if ia is not None:
            got_a.append(ia); a.complete("w", ia)
        if ib is not None:
            got_b.append(ib); b.complete("w", ib)
    # exclusive-create claims: a strict partition, nothing lost or doubled
    assert not set(got_a) & set(got_b)
    assert sorted(got_a + got_b) == list(range(12))
    assert a.remaining() == 0 and b.remaining() == 0
    a.stop(); b.stop()


def test_fs_queue_expired_lease_is_reclaimed(tmp_path):
    keys = [f"k{i}" for i in range(4)]
    dead = FsWorkQueue(4, keys=keys, lease_size=2, root=str(tmp_path),
                       host_id="dead", lease_ttl=0.25)
    first = dead.claim("w")
    assert first is not None
    dead.stop()               # kills the heartbeat thread — a portable SIGKILL
    time.sleep(0.6)           # > ttl: the held leases are now stale
    surv = FsWorkQueue(4, keys=keys, lease_size=4, root=str(tmp_path),
                       host_id="surv", lease_ttl=0.25)
    got = _drain(surv)
    assert sorted(got) == [0, 1, 2, 3]   # incl. the dead host's lease tail
    st = surv.stats()["w"]
    assert st.reclaimed >= 1 and st.stolen_by >= st.reclaimed
    rec = json.load(open(tmp_path / f"lease_k{first}.json"))
    assert rec["host"] == "surv" and rec["steals"] >= 1
    surv.stop()


def test_fs_queue_live_lease_is_not_stolen(tmp_path):
    keys = ["x", "y"]
    a = FsWorkQueue(2, keys=keys, lease_size=1, root=str(tmp_path),
                    host_id="A", lease_ttl=0.4)
    held = a.claim("w")
    b = FsWorkQueue(2, keys=keys, lease_size=2, root=str(tmp_path),
                    host_id="B", lease_ttl=0.4)
    other = b.claim("w", block=False)
    assert other is not None and other != held
    # b has the rest; a's lease is heartbeat-fresh across several ttls
    deadline = time.monotonic() + 1.2
    while time.monotonic() < deadline:
        assert b.claim("w", block=False) is None or pytest.fail("stole a live lease")
        time.sleep(0.1)
    a.complete("w", held)
    b.complete("w", other)
    assert b.remaining() == 0
    a.stop(); b.stop()


def test_fs_queue_done_is_never_stolen(tmp_path):
    keys = ["only"]
    a = FsWorkQueue(1, keys=keys, lease_size=1, root=str(tmp_path),
                    host_id="A", lease_ttl=0.2)
    idx = a.claim("w")
    a.complete("w", idx)
    a.stop()
    time.sleep(0.5)           # well past ttl: done markers do not expire
    b = FsWorkQueue(1, keys=keys, lease_size=1, root=str(tmp_path),
                    host_id="B", lease_ttl=0.2)
    assert b.claim("w", block=False) is None
    assert b.remaining() == 0
    b.stop()


def test_fs_queue_corrupt_lease_expires_by_mtime(tmp_path):
    (tmp_path / "lease_k0.json").write_text("{torn write")
    q = FsWorkQueue(1, keys=["k0"], lease_size=1, root=str(tmp_path),
                    host_id="A", lease_ttl=0.2)
    assert q.claim("w", block=False) is None    # fresh mtime: not expired yet
    old = time.time() - 5.0
    os.utime(tmp_path / "lease_k0.json", (old, old))
    idx = q.claim("w", block=False)
    assert idx == 0                             # reclaimed via mtime fallback
    q.stop()


def test_fs_queue_stop_unblocks_blocking_claim(tmp_path):
    import threading

    keys = ["x", "y"]
    a = FsWorkQueue(2, keys=keys, lease_size=2, root=str(tmp_path),
                    host_id="A", lease_ttl=60.0)
    assert a.claim("w") is not None
    b = FsWorkQueue(2, keys=keys, lease_size=2, root=str(tmp_path),
                    host_id="B", lease_ttl=60.0, poll_s=0.05)
    got = []
    t = threading.Thread(target=lambda: got.append(b.claim("w")), daemon=True)
    t.start()                 # parks: A holds both keys, neither done
    time.sleep(0.2)
    assert t.is_alive()
    b.stop()
    t.join(timeout=2.0)
    assert not t.is_alive() and got == [None]
    a.stop()


def test_fs_queue_unverified_done_lease_is_reclaimed(tmp_path):
    """A done marker whose commit never reached the manifest (lost merge
    on a flock-less mount) must be recomputed, not trusted: nobody
    heartbeats a done lease and resumes skip it, so trusting it would
    silently leave the grid incomplete."""
    committed: set = set()
    _forge_done_lease(tmp_path, "a")
    q = FsWorkQueue(2, keys=["a", "b"], lease_size=2, root=str(tmp_path),
                    host_id="A", lease_ttl=60.0,
                    done_check=lambda k: k in committed)
    got = _drain(q)
    assert sorted(got) == [0, 1]          # "a" recomputed despite its marker
    st = q.stats()["w"]
    assert st.reclaimed >= 1
    rec = json.load(open(tmp_path / "lease_a.json"))
    assert rec["host"] == "A" and rec["state"] == "done" and rec["steals"] >= 1
    assert q.remaining() == 0
    q.stop()


def test_fs_queue_verified_done_lease_is_trusted(tmp_path):
    """The same done marker IS skipped once the check confirms its cells
    are in the manifest — done_check gates recompute, it never forces it."""
    _forge_done_lease(tmp_path, "a")
    q = FsWorkQueue(2, keys=["a", "b"], lease_size=2, root=str(tmp_path),
                    host_id="A", lease_ttl=60.0, done_check=lambda k: True)
    assert _drain(q) == [1]
    assert q.remaining() == 0
    q.stop()


def _forge_done_lease(root, key):
    (root / f"lease_{key}.json").write_text(json.dumps({
        "key": key, "host": "ghost", "worker": "w", "claimed": 0.0,
        "heartbeat": 0.0, "state": "done", "steals": 0,
    }))


def test_fs_queue_complete_survives_marker_write_failure(tmp_path, monkeypatch):
    """A transiently unwritable shared FS during the done-marker write
    must not abort the scan: the cell is already committed to the
    manifest, the marker is just a skip hint.  The lease is left to
    expire, so a peer recomputes (idempotent)."""
    import repro.runtime.workqueue as wq

    q = wq.FsWorkQueue(1, keys=["k"], lease_size=1, root=str(tmp_path),
                       host_id="A", lease_ttl=0.2)
    idx = q.claim("w")
    assert idx == 0
    monkeypatch.setattr(
        wq, "_overwrite_json",
        lambda path, payload: (_ for _ in ()).throw(OSError("fs hiccup")),
    )
    q.complete("w", idx)                  # must not raise
    monkeypatch.undo()
    q.stop()
    assert q.remaining() == 0             # locally retired regardless
    rec = json.load(open(tmp_path / "lease_k.json"))
    assert rec["state"] == "leased"       # marker never landed
    time.sleep(0.5)                       # > ttl: the stale lease expires
    peer = wq.FsWorkQueue(1, keys=["k"], lease_size=1, root=str(tmp_path),
                          host_id="B", lease_ttl=0.2)
    assert peer.claim("w", block=False) == 0   # ... and a peer reclaims it
    peer.stop()


def test_fs_queue_heartbeat_survives_slow_claim_scan(tmp_path, monkeypatch):
    """A slow shared FS making claim's refill listdir take several ttls
    must not starve the heartbeat thread: held leases stay fresh through
    the stall, so peers never see them expire and never thrash-recompute
    live work.  (The old code held the queue lock across the O(grid) FS
    scan; the heartbeat shares that lock for its bookkeeping.)"""
    import threading

    import repro.runtime.workqueue as wq

    keys = ["x", "y"]
    a = FsWorkQueue(2, keys=keys, lease_size=1, root=str(tmp_path),
                    host_id="A", lease_ttl=0.4)
    held = a.claim("w")                   # heartbeat thread now live
    assert held is not None
    b = FsWorkQueue(2, keys=keys, lease_size=1, root=str(tmp_path),
                    host_id="B", lease_ttl=0.4)
    other = b.claim("w", block=False)
    assert other is not None
    b.complete("w", other)                # only A's live lease is left

    real_listdir = os.listdir
    calls = {"n": 0}

    def slow_listdir(path):
        calls["n"] += 1
        if calls["n"] == 1:               # stall only A's scan below
            time.sleep(1.2)
        return real_listdir(path)

    monkeypatch.setattr(wq.os, "listdir", slow_listdir)
    t = threading.Thread(
        target=lambda: a.claim("w2", block=False), daemon=True
    )
    t.start()                             # parks ~3 ttl inside the refill scan
    time.sleep(0.6)                       # mid-stall, > ttl since it began
    c = FsWorkQueue(2, keys=keys, lease_size=2, root=str(tmp_path),
                    host_id="C", lease_ttl=0.4)
    assert c.claim("w", block=False) is None   # A's lease stayed fresh
    t.join(timeout=10.0)
    assert not t.is_alive()
    a.complete("w", held)
    a.stop(); b.stop(); c.stop()


def test_fs_queue_requires_root_and_unique_keys(tmp_path):
    with pytest.raises(ValueError, match="root"):
        FsWorkQueue(2)
    with pytest.raises(ValueError, match="unique"):
        FsWorkQueue(2, keys=["a", "a"], root=str(tmp_path))
    with pytest.raises(ValueError, match="2 keys for 3"):
        FsWorkQueue(3, keys=["a", "b"], root=str(tmp_path))


# --------------------------------------------- manifest read-merge-write


def test_checkpoint_concurrent_committers_union(tmp_path):
    """Two processes share one checkpoint dir; each holds a process-local
    manifest dict.  Interleaved commits must UNION on disk — the old
    write-from-local-state dropped whichever entries the other process
    committed in between (lost update)."""
    fp = config_fingerprint({"scan": 1})
    a = ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=2, n_blocks=2)
    b = ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=2, n_blocks=2)
    a.commit_cell(0, 0, {"x": np.arange(2)})
    b.commit_cell(1, 1, {"x": np.arange(3)})     # b never saw a's commit
    a.commit_cell(0, 1, {"x": np.arange(4)})     # a never saw b's commit
    disk = json.load(open(tmp_path / "manifest.json"))
    assert set(disk["completed"]) == {"0.0", "1.1", "0.1"}
    # refresh folds peers' commits into memory without writing
    b.refresh()
    assert b.completed_cells() == {(0, 0), (1, 1), (0, 1)}
    assert (1, 0) in b.pending_cells()


def test_checkpoint_commit_clears_merged_failure(tmp_path):
    fp = config_fingerprint({"scan": 2})
    a = ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=2)
    b = ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=2)
    a.record_failure(0, "transient decode error")
    b.commit_batch(0, {"x": np.arange(2)})       # peer retried and succeeded
    disk = json.load(open(tmp_path / "manifest.json"))
    assert "0" in disk["completed"] and "0" not in disk["failed"]
    # the stale failure does not resurrect through a's next write either
    a.commit_batch(1, {"x": np.arange(2)})
    disk = json.load(open(tmp_path / "manifest.json"))
    assert set(disk["completed"]) == {"0", "1"} and disk["failed"] == {}


def test_checkpoint_same_cell_commit_race_no_tmp_collision(tmp_path):
    """Cross-process double completion of ONE cell is a supported race
    (lease steal, TTL expiry): concurrent committers must not share a tmp
    path.  The old fixed ``shard + '.tmp.npz'`` let one writer truncate
    the bytes the other was about to publish — a torn shard recorded
    completed — and the loser's os.replace raised FileNotFoundError,
    aborting its scan."""
    import threading

    fp = config_fingerprint({"scan": 3})
    cks = [
        ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=1, n_blocks=1)
        for _ in range(2)
    ]
    payload = {"x": np.arange(4096)}
    barrier = threading.Barrier(2)
    errs = []

    def commit(ck):
        try:
            for _ in range(25):
                barrier.wait(timeout=30)
                ck.commit_cell(0, 0, payload)
        except BaseException as e:  # noqa: BLE001 — asserted below
            errs.append(e)

    threads = [threading.Thread(target=commit, args=(ck,)) for ck in cks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    np.testing.assert_array_equal(cks[0].load_cell(0, 0)["x"], payload["x"])
    assert not [f for f in os.listdir(tmp_path) if ".tmp" in f]


def test_scheduler_verifies_done_leases_against_manifest(tmp_path):
    """End-to-end plumbing of the manifest arbiter: the session passes a
    (batch, block) probe as ``cell_committed`` and the scheduler keys it
    by work item — a forged done lease whose cells are absent from the
    manifest is recomputed; one whose cells are present is skipped."""
    from repro.runtime.scheduler import CellScheduler

    class _Ax:
        def __init__(self, index):
            self.index = index

    def run(root, committed):
        _forge_done_lease(root, "b000001")
        sched = CellScheduler(
            [_Ax(0), _Ax(1)], [_Ax(0)], placement="marker-major",
            lease_size=1, backend="shared-fs",
            backend_opts={
                "root": str(root), "host_id": "A", "lease_ttl": 60.0,
                "cell_committed": lambda b, k: (b, k) in committed,
            },
        )
        got = []
        while (c := sched.claim("w")) is not None:
            idx, item = c
            got.append(item.batch.index)
            sched.complete("w", idx)
        sched.stop()
        return got

    lying, truthful = tmp_path / "lying", tmp_path / "truthful"
    lying.mkdir(); truthful.mkdir()
    assert sorted(run(lying, committed=set())) == [0, 1]   # recomputed
    assert run(truthful, committed={(1, 0)}) == [0]        # trusted


# ------------------------------------------------------------- validation


def test_shared_fs_requires_checkpoint_dir():
    from repro.api.specs import ExecSpec, ScanConfig

    with pytest.raises(ValueError, match="checkpoint_dir"):
        ScanConfig.from_specs(executor=ExecSpec(backend="shared-fs"))
    with pytest.raises(ValueError, match="backend"):
        ExecSpec(backend="smoke-signals").validate()
    with pytest.raises(ValueError, match="lease_ttl"):
        ExecSpec(backend="shared-fs", lease_ttl=0.0).validate()


def test_cli_shared_fs_requires_checkpoint_dir():
    from repro.launch.gwas import cmd_scan

    with pytest.raises(SystemExit, match="checkpoint-dir"):
        cmd_scan([
            "--genotypes", "x.bed", "--pheno", "p.tsv", "--out", "o",
            "--exec-backend", "shared-fs",
        ])


# ------------------------------- multi-process semantics (subprocesses)
#
# Children run real independent interpreters against one checkpoint dir on
# tmpfs — the same coordination surface N hosts would share over NFS.

_HOST = textwrap.dedent(
    """
    import json, os, sys, time
    from repro.api import ExecSpec, GridSpec, Study, TsvWriter

    bed, pheno, cov, ck, out, host_id = sys.argv[1:7]
    ttl = float(sys.argv[7])
    cell_sleep = float(sys.argv[8])
    study = Study.from_files(bed, pheno, cov)
    # 6 trait blocks per batch: one marker-major item (6 cells) overflows
    # the executor's bounded results queue (4 slots), so a slow consumer
    # parks the worker MID-item — which is what lets the SIGKILL test kill
    # a host with a partially-committed lease.
    session = study.plan(
        grid=GridSpec(batch_markers=64, block_m=64, block_n=128, block_p=2,
                      trait_block=2),
        hit_threshold_nlp=2.0,
        executor=ExecSpec(devices=1, lease_batches=2, backend="shared-fs",
                          host_id=host_id, lease_ttl=ttl),
        checkpoint_dir=ck,
    ).run()

    def progress(m):
        print("CELL", flush=True)       # the parent's kill trigger
        if cell_sleep:
            time.sleep(cell_sleep)

    session.progress = progress
    session.stream_to(TsvWriter(out))
    print("INFO " + json.dumps({
        "executor": session.executor_info,
        "live": session.metrics.summary()["live_cells"],
        "replayed": session.metrics.summary()["replayed_cells"],
    }), flush=True)
    """
)


@pytest.fixture(scope="module")
def serial_ref(cohort_files, tmp_path_factory):
    """Serial single-process reference outputs for the subprocess cohort."""
    from repro.api import GridSpec, Study, TsvWriter

    study = Study.from_files(
        cohort_files["bed"], cohort_files["pheno"], cohort_files["cov"]
    )
    out = str(tmp_path_factory.mktemp("serial_ref"))
    study.plan(
        grid=GridSpec(batch_markers=64, block_m=64, block_n=128, block_p=2,
                      trait_block=2),
        hit_threshold_nlp=2.0,
    ).run().stream_to(TsvWriter(out))
    return _read_out(out)


TOTAL_CELLS = 60   # 10 batches (600 markers / 64) x 6 trait blocks (12 / 2)


_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)


def _spawn_host(cohort_files, ck, out, host_id, *, ttl=60.0, cell_sleep=0.0):
    env = dict(os.environ, PYTHONPATH=_SRC, JAX_PLATFORMS="cpu")
    # Host-labelled scratch cwd under the test's tmp tree: any relative
    # path a child ever writes lands here, never in the repo checkout
    # (the conftest guard fails tests that dirty the repo root).
    scratch = os.path.join(os.path.dirname(out), f"scratch-{host_id}")
    os.makedirs(scratch, exist_ok=True)
    return subprocess.Popen(
        [sys.executable, "-c", _HOST, cohort_files["bed"],
         cohort_files["pheno"], cohort_files["cov"], ck, out, host_id,
         str(ttl), str(cell_sleep)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env, cwd=scratch,
    )


def _host_info(stdout):
    for line in stdout.splitlines():
        if line.startswith("INFO "):
            return json.loads(line[5:])
    raise AssertionError(f"no INFO line in child stdout: {stdout[-500:]}")


def test_two_concurrent_hosts_byte_identical(cohort_files, serial_ref, tmp_path):
    ck = str(tmp_path / "ck")
    outs = [str(tmp_path / "host_a"), str(tmp_path / "host_b")]
    procs = [
        _spawn_host(cohort_files, ck, outs[0], "hostA"),
        _spawn_host(cohort_files, ck, outs[1], "hostB"),
    ]
    results = [p.communicate(timeout=600) for p in procs]
    for p, (stdout, stderr) in zip(procs, results):
        assert p.returncode == 0, stderr[-3000:]
    infos = [_host_info(stdout) for stdout, _ in results]
    # BOTH hosts emit the complete grid, byte-identical to the serial scan
    for out in outs:
        assert _read_out(out) == serial_ref
    # the grid was actually split: each host computed some cells live and
    # replayed its peer's committed cells; together they covered everything
    for info in infos:
        assert info["executor"]["backend"] == "shared-fs"
        assert info["live"] + info["replayed"] == TOTAL_CELLS
    assert infos[0]["live"] + infos[1]["live"] >= TOTAL_CELLS  # >=: steal overlap
    assert all(info["live"] > 0 for info in infos)
    # host-qualified worker labels in the stats
    assert all(
        w.startswith(("hostA/", "hostB/"))
        for info in infos for w in info["executor"]["workers"]
    )


def test_sigkilled_host_tail_reclaimed_by_survivor(
    cohort_files, serial_ref, tmp_path
):
    ck = str(tmp_path / "ck")
    victim_out = str(tmp_path / "victim")
    victim = _spawn_host(
        cohort_files, ck, victim_out, "victim", ttl=1.5, cell_sleep=0.3
    )
    # Let it claim leases and commit a couple of cells, then SIGKILL —
    # no teardown runs, its lease files simply stop heartbeating.
    cells_seen = 0
    for line in victim.stdout:
        if line.startswith("CELL"):
            cells_seen += 1
            if cells_seen >= 2:
                break
    os.kill(victim.pid, signal.SIGKILL)
    victim.wait(timeout=60)
    victim.stdout.close(); victim.stderr.close()
    assert victim.returncode != 0

    surv_out = str(tmp_path / "survivor")
    surv = _spawn_host(cohort_files, ck, surv_out, "survivor", ttl=1.5)
    stdout, stderr = surv.communicate(timeout=600)
    assert surv.returncode == 0, stderr[-3000:]
    info = _host_info(stdout)

    # the survivor reclaimed the dead host's expired lease tail ...
    stats = info["executor"]["workers"]
    assert sum(w["reclaimed"] for w in stats.values()) >= 1
    # ... finished the grid, and its outputs are byte-identical to serial
    assert info["live"] + info["replayed"] == TOTAL_CELLS
    assert _read_out(surv_out) == serial_ref


# --------------------------------------------------- property: partition


def test_fs_queue_claims_partition_property(tmp_path):
    """Any interleaving of two hosts' claims yields a partition of the item
    set: no item claimed twice, none lost (huge ttl: no expiry stealing, so
    the partition is strict)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        order=st.lists(st.sampled_from(["A", "B"]), min_size=1, max_size=40),
        lease_a=st.integers(min_value=1, max_value=5),
        lease_b=st.integers(min_value=1, max_value=5),
        n_items=st.integers(min_value=1, max_value=12),
    )
    def check(order, lease_a, lease_b, n_items):
        import tempfile

        root = tempfile.mkdtemp(dir=str(tmp_path))
        keys = [f"k{i}" for i in range(n_items)]
        hosts = {
            "A": FsWorkQueue(n_items, keys=keys, lease_size=lease_a,
                             root=root, host_id="A", lease_ttl=1e6),
            "B": FsWorkQueue(n_items, keys=keys, lease_size=lease_b,
                             root=root, host_id="B", lease_ttl=1e6),
        }
        claims = {"A": [], "B": []}
        for who in order + ["A"] * n_items + ["B"] * n_items:
            idx = hosts[who].claim("w", block=False)
            if idx is not None:
                claims[who].append(idx)
                hosts[who].complete("w", idx)
        assert not set(claims["A"]) & set(claims["B"])
        assert sorted(claims["A"] + claims["B"]) == list(range(n_items))
        for q in hosts.values():
            assert q.remaining() == 0
            q.stop()

    check()
