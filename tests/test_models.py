"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs;
plus exact prefill/decode consistency and scan-vs-unrolled equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.models import api as M
from repro.train.data import make_batch
from repro.train.train_step import TrainStepConfig, build_train_step, init_train_state

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
KEY = jax.random.PRNGKey(0)


def _reduced(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # permissive capacity so consistency is exact
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
        )
    return cfg


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = _reduced(arch)
    params = M.init_model(cfg, KEY, max_positions=64)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    logits, aux = M.train_logits(cfg, params, batch)
    b = SHAPE.global_batch
    assert logits.shape[0] == b and logits.shape[2] == cfg.vocab
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg = _reduced(arch)
    tcfg = TrainStepConfig()
    params, opt = init_train_state(cfg, tcfg, KEY, max_positions=64)
    step = build_train_step(cfg, tcfg=tcfg, donate=False)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, pair: acc, jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    )
    total_move = sum(jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()), params, params2)))
    assert total_move > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = _reduced(arch)
    b, s = 2, 12
    if cfg.family == "encdec":
        from repro.models import encdec as E

        params = E.init_encdec_params(cfg, KEY, max_positions=64)
        frames = jax.random.normal(KEY, (b, cfg.encoder_len, cfg.d_model), jnp.float32) * 0.02
        tokens = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
        full = E.forward_train(cfg, params, frames, tokens)
        last, caches = E.prefill(cfg, params, frames, tokens[:, :s], cache_capacity=s + 4)
        dec, _ = E.decode(cfg, params, tokens[:, s], jnp.full((b,), s, jnp.int32), caches)
    else:
        from repro.models import transformer as T

        params = T.init_params(cfg, KEY)
        extra = None
        if cfg.family == "vlm":
            patches = 4
            extra = jax.random.normal(KEY, (b, patches, cfg.d_model), jnp.float32) * 0.02
            tokens = jax.random.randint(KEY, (b, s + 1 - patches), 0, cfg.vocab)
            pos = jnp.broadcast_to(jnp.arange(s + 1), (3, b, s + 1))
            full, _ = T.forward_train(cfg, params, tokens, pos, extra_embeds=extra)
            last, caches = T.prefill(cfg, params, tokens[:, :-1], pos[:, :, :s],
                                     cache_capacity=s + 4, extra_embeds=extra)
            dec, _ = T.decode(cfg, params, tokens[:, -1], jnp.full((b,), s, jnp.int32), caches)
        else:
            tokens = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
            pos = jnp.broadcast_to(jnp.arange(s + 1), (b, s + 1))
            full, _ = T.forward_train(cfg, params, tokens, pos)
            last, caches = T.prefill(cfg, params, tokens[:, :s], pos[:, :s], cache_capacity=s + 4)
            dec, _ = T.decode(cfg, params, tokens[:, s], jnp.full((b,), s, jnp.int32), caches)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, s - 1]), atol=5e-2)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, s]), atol=5e-2)


@pytest.mark.parametrize("arch", ["gemma2-9b", "recurrentgemma-2b", "granite-moe-1b-a400m"])
def test_scan_vs_unrolled_identical(arch):
    """The dry-run's unrolled accounting mode must be numerically identical
    to the production scanned mode."""
    cfg = _reduced(arch)
    params = M.init_model(cfg, KEY, max_positions=64)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    logits_scan, _ = M.train_logits(cfg, params, batch)
    cfg_unroll = dataclasses.replace(cfg, scan_layers=False)
    logits_unroll, _ = M.train_logits(cfg_unroll, params, batch)
    # XLA fuses the two program shapes differently, so bf16 activations
    # round differently — equality holds to a few bf16 ulps (recurrent
    # families compound the rounding through the time scan).
    np.testing.assert_allclose(
        np.asarray(logits_scan), np.asarray(logits_unroll), atol=3e-2
    )


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "gemma2-9b"])
def test_int8_kv_cache_decode(arch):
    """int8 KV cache: decode logits within ~1.5% of the bf16-cache path."""
    cfg = dataclasses.replace(_reduced(arch), kv_cache_dtype="int8")
    from repro.models import transformer as T

    params = T.init_params(cfg, KEY)
    b, s = 2, 12
    tokens = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(s + 1), (b, s + 1))
    full, _ = T.forward_train(cfg, params, tokens, pos)
    _, caches = T.prefill(cfg, params, tokens[:, :s], pos[:, :s], cache_capacity=s + 4)
    # cache payloads really are int8
    leaves = jax.tree.leaves(caches)
    assert any(l.dtype == jnp.int8 for l in leaves)
    dec, _ = T.decode(cfg, params, tokens[:, s], jnp.full((b,), s, jnp.int32), caches)
    err = float(jnp.abs(dec - full[:, -1]).max())
    assert err < 0.08 * max(float(jnp.abs(full[:, -1]).max()), 1.0)


@pytest.mark.parametrize("arch,chunk", [("gemma2-9b", 7), ("deepseek-coder-33b", 8), ("whisper-small", 8)])
def test_chunked_attention_matches_dense(arch, chunk):
    """Flash-style chunked attention == dense attention to bf16 rounding,
    including ragged chunk sizes and local/global/bidirectional masks."""
    cfg = _reduced(arch)
    b, s = 2, 24
    if cfg.family == "encdec":
        from repro.models import encdec as E

        params = E.init_encdec_params(cfg, KEY, max_positions=64)
        frames = jax.random.normal(KEY, (b, cfg.encoder_len, cfg.d_model), jnp.float32) * 0.02
        tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
        dense = E.forward_train(cfg, params, frames, tokens)
        chunked = E.forward_train(
            dataclasses.replace(cfg, attn_chunk=chunk), params, frames, tokens
        )
    else:
        from repro.models import transformer as T

        params = T.init_params(cfg, KEY)
        tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        dense, _ = T.forward_train(cfg, params, tokens, pos)
        chunked, _ = T.forward_train(
            dataclasses.replace(cfg, attn_chunk=chunk), params, tokens, pos
        )
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), atol=2e-2)


def test_local_attention_respects_window():
    """A token beyond the local window cannot influence a local-only model."""
    cfg = dataclasses.replace(
        _reduced("gemma2-9b"), block_pattern=("local",), n_layers=2, local_window=4
    )
    from repro.models import transformer as T

    params = T.init_params(cfg, KEY)
    b, s = 1, 16
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    base, _ = T.forward_train(cfg, params, tokens, pos)
    perturbed = tokens.at[0, 0].set((tokens[0, 0] + 1) % cfg.vocab)
    out, _ = T.forward_train(cfg, params, perturbed, pos)
    # position 0 changed -> positions >= window*n_layers unaffected
    far = cfg.local_window * cfg.n_layers
    np.testing.assert_allclose(
        np.asarray(base[:, far:]), np.asarray(out[:, far:]), atol=1e-5
    )
    assert np.abs(np.asarray(base[:, 0]) - np.asarray(out[:, 0])).max() > 1e-4


def test_mrope_sections_differ_from_1d():
    cfg = _reduced("qwen2-vl-7b")
    from repro.models import layers as L

    pos1d = jnp.broadcast_to(jnp.arange(8), (2, 8))
    pos3d = jnp.stack([pos1d, pos1d * 2, pos1d * 3])
    a1 = L.rope_angles(cfg, pos1d)
    a3 = L.rope_angles(cfg, pos3d)
    assert a1.shape == a3.shape
    assert np.abs(np.asarray(a1) - np.asarray(a3)).max() > 1e-3
