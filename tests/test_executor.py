"""The multi-device grid executor (DESIGN.md §12).

The contract under test: the (marker-batch x trait-block) grid drained by
N devices through the work-stealing ``CellScheduler`` produces *bitwise*
the outputs of the serial single-device walk — for dense, fused, and lmm
(incl. LOCO) engines, under both placement policies, and across resumes
whose device count differs from the run that wrote the checkpoint.  Real
multi-device semantics run on 8 fake CPU devices in a subprocess (the
parent must keep seeing one device); the scheduler, executor machinery,
spec plumbing, and metrics are covered in-process.
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.api import ExecSpec, GridSpec, Study, TsvWriter
from repro.api.session import MultiDeviceExecutor, SerialExecutor
from repro.io import plink
from repro.runtime.prefetch import MarkerBatch, TraitBlock
from repro.runtime.scheduler import CellRun, CellScheduler


@pytest.fixture(scope="module")
def source(cohort_files):
    return plink.PlinkBed(cohort_files["bed"])


@pytest.fixture(scope="module")
def study(source, cohort):
    return Study.from_arrays(source, cohort.phenotypes, cohort.covariates)


def _grid(**kw):
    base = dict(batch_markers=128, block_m=64, block_n=128, block_p=4)
    base.update(kw)
    return GridSpec(**base)


def _batches(n, size=10):
    return [
        MarkerBatch(index=i, lo=i * size, hi=(i + 1) * size, source_id=0,
                    local_lo=i * size, local_hi=(i + 1) * size)
        for i in range(n)
    ]


def _blocks(n, width=4):
    return [TraitBlock(index=k, lo=k * width, hi=(k + 1) * width) for k in range(n)]


# ---------------------------------------------------------------- scheduler


def test_scheduler_marker_major_items_sweep_blocks():
    sched = CellScheduler(_batches(3), _blocks(2), placement="marker-major")
    assert sched.n_items == 3 and sched.n_cells == 6
    for run in sched.items:
        assert [k.index for k in run.blocks] == [0, 1]
    assert [run.batch.index for run in sched.items] == [0, 1, 2]


def test_scheduler_trait_major_items_are_block_major_cells():
    sched = CellScheduler(_batches(3), _blocks(2), placement="trait-major")
    assert sched.n_items == 6 and sched.n_cells == 6
    # block-major enumeration: a contiguous lease stays in one panel column
    assert [(r.batch.index, r.blocks[0].index) for r in sched.items] == [
        (0, 0), (1, 0), (2, 0), (0, 1), (1, 1), (2, 1)
    ]


def test_scheduler_pending_filter_mid_panel():
    pending = {(0, 1), (2, 0), (2, 1)}   # batch 0 half done, batch 1 done
    sched = CellScheduler(_batches(3), _blocks(2), pending)
    assert [(r.batch.index, [k.index for k in r.blocks]) for r in sched.items] == [
        (0, [1]), (2, [0, 1])
    ]
    assert sched.n_cells == 3


def test_scheduler_lease_capped_to_spread_over_workers():
    """Short scans must still use every slot: the lease is capped at
    n_items / n_workers, otherwise the first claims would take everything
    and leave only unstealable <=1-item leases behind."""
    sched = CellScheduler(_batches(6), _blocks(3), lease_size=2, n_workers=4)
    assert sched.lease_size == 1
    assert all(sched.claim(f"w{i}") is not None for i in range(4))
    # plenty of items: the cap does not bind
    assert CellScheduler(_batches(24), _blocks(1), lease_size=2, n_workers=4).lease_size == 2
    # no worker count given (tests, single-slot callers): untouched
    assert CellScheduler(_batches(6), _blocks(1), lease_size=4).lease_size == 4


def test_scheduler_rejects_unknown_placement():
    with pytest.raises(ValueError, match="placement"):
        CellScheduler(_batches(1), _blocks(1), placement="diagonal")


def test_scheduler_drains_under_contention():
    sched = CellScheduler(_batches(24), _blocks(3), lease_size=4)
    seen, lock = [], threading.Lock()

    def drain(worker):
        while True:
            claim = sched.claim(worker)
            if claim is None:
                return
            idx, run = claim
            with lock:
                seen.extend((run.batch.index, k.index) for k in run.blocks)
            sched.complete(worker, idx)

    threads = [threading.Thread(target=drain, args=(f"w{i}",)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(seen) == sorted((b, k) for b in range(24) for k in range(3))
    assert len(seen) == len(set(seen))  # items never claimed twice
    assert sched.remaining() == 0


# ------------------------------------------------------------------- specs


def test_exec_spec_validation(study):
    with pytest.raises(ValueError, match="devices"):
        ExecSpec(devices=-1).validate()
    with pytest.raises(ValueError, match="placement"):
        study.plan(executor=ExecSpec(placement="diag"))
    with pytest.raises(ValueError, match="lease_batches"):
        study.plan(executor=ExecSpec(lease_batches=0))


def test_exec_spec_roundtrip_and_fingerprint_free(study):
    from repro.api.specs import ScanConfig

    cfg = ScanConfig.from_specs(
        executor=ExecSpec(devices=4, placement="trait-major", lease_batches=3)
    )
    assert cfg.exec_spec() == ExecSpec(4, "trait-major", 3)
    # executor shape never enters the checkpoint identity: a scan cut under
    # one device count must resume under any other
    assert cfg.fingerprint_payload() == ScanConfig().fingerprint_payload()


def test_more_devices_than_visible_rejected(study):
    session = study.plan(grid=_grid(), executor=ExecSpec(devices=97)).run()
    with pytest.raises(ValueError, match="devices=97"):
        next(session.events())


def test_custom_step_rejected_under_multi_device(study):
    """The shim's swappable ``_step`` hook carries a single prolog memo —
    it cannot ride N worker threads, and silently dropping it would lose
    the caller's patched math; refuse loudly."""
    from repro.api.session import ScanSession

    prep = study.plan(grid=_grid(), executor=ExecSpec(devices=2)).prepare()
    session = ScanSession(prep, step=lambda *a: {})
    with pytest.raises(ValueError, match="custom step"):
        next(session.events())


def test_mesh_and_multi_device_exclusive(study):
    import dataclasses

    import jax
    from jax.sharding import Mesh

    from repro.api.session import ScanSession

    prep = study.plan(grid=_grid(), executor=ExecSpec(devices=2)).prepare()
    meshed = dataclasses.replace(prep, mesh=Mesh(np.array(jax.devices()[:1]), ("model",)))
    with pytest.raises(ValueError, match="exclusive"):
        ScanSession(meshed)


# ----------------------------------------- executor machinery (one device)


def _collect(executor, todo, pending=None):
    out = {}
    for cell, timing in executor.cells(todo, pending):
        out[(cell.batch_index, cell.block_index)] = cell
        assert timing.wall_s >= 0 and timing.n_markers == cell.n_markers
    return out


def test_multi_executor_machinery_matches_serial(study):
    """The worker/queue/scheduler machinery with a single slot must produce
    exactly the serial walk's cells (same set, same arrays bitwise) — the
    device count then only changes who computes, which the 8-fake-device
    subprocess asserts."""
    plan = study.plan(grid=_grid(trait_block=4), hit_threshold_nlp=2.0)
    prep = plan.prepare()
    ref = _collect(SerialExecutor(prep), prep.batches)
    for placement in ("marker-major", "trait-major"):
        got = _collect(
            MultiDeviceExecutor(prep, n_devices=1, placement=placement),
            prep.batches,
        )
        assert set(got) == set(ref)
        for key, cell in got.items():
            for k, v in ref[key].arrays.items():
                np.testing.assert_array_equal(v, cell.arrays[k], err_msg=f"{key}:{k}")


_PIPELINE_THREADS = (
    "scan-device", "slot-decode", "slot-tail", "panel-prefetch-dev"
)


def _leaked_pipeline_threads():
    import time as _time

    # teardown joins everything before the generator's close() returns;
    # the brief poll only absorbs scheduler jitter on loaded CI boxes
    for _ in range(50):
        alive = [
            t for t in threading.enumerate()
            if t.name.startswith(_PIPELINE_THREADS) and t.is_alive()
        ]
        if not alive:
            return []
        _time.sleep(0.02)
    return alive


def test_multi_executor_propagates_worker_errors(study):
    plan = study.plan(grid=_grid(trait_block=4))
    prep = plan.prepare()
    ex = MultiDeviceExecutor(prep, n_devices=1)
    boom_calls = {"n": 0}

    real_prepare = prep.engine.prepare_batch

    def exploding(source, batch, ctx):
        boom_calls["n"] += 1
        if boom_calls["n"] > 1:
            raise RuntimeError("decode exploded")
        return real_prepare(source, batch, ctx)

    prep.engine.prepare_batch = exploding
    try:
        with pytest.raises(RuntimeError, match="decode exploded"):
            list(ex.cells(prep.batches, None))
    finally:
        prep.engine.prepare_batch = real_prepare
    assert not _leaked_pipeline_threads()


def test_multi_executor_early_close_joins_workers(study):
    plan = study.plan(grid=_grid(trait_block=4))
    prep = plan.prepare()
    gen = MultiDeviceExecutor(prep, n_devices=1).cells(prep.batches, None)
    next(gen)
    gen.close()
    assert not _leaked_pipeline_threads()


def test_pipelined_teardown_releases_slots_mid_stream(study, monkeypatch):
    """Closing ``events()`` mid-scan tears down the whole per-slot
    pipeline: decode pool, tail, and panel look-ahead threads are joined,
    and every slot is reset (dropping its staged device panel blocks and
    engine arrays — nothing stays pinned on the devices)."""
    import repro.api.session as session_mod

    plan = study.plan(grid=_grid(trait_block=4))
    prep = plan.prepare()
    resets = []
    real_reset = session_mod._Slot.reset

    def spy(self):
        resets.append(self.label)
        return real_reset(self)

    monkeypatch.setattr(session_mod._Slot, "reset", spy)
    ex = MultiDeviceExecutor(prep, n_devices=1, slot_prefetch=2)
    gen = ex.cells(prep.batches, None)
    next(gen)
    gen.close()
    assert resets  # every worker's finally ran its slot teardown
    assert not _leaked_pipeline_threads()


def test_panel_view_release_drops_staged_blocks(study):
    """The slot-teardown primitive: release() empties the per-device LRU
    (no pinned panel buffers survive the scan) but the view restages on
    demand with identical bytes."""
    import jax

    prep = study.plan(grid=_grid(trait_block=4)).prepare()
    view = prep.panels.device_view(jax.devices()[0])
    blk = prep.trait_blocks[0]
    before = np.asarray(view.device_block(blk))
    assert len(view._dev) == 1
    view.release()
    assert len(view._dev) == 0
    np.testing.assert_array_equal(np.asarray(view.device_block(blk)), before)


# ----------------------------------------------------------------- metrics


def test_session_metrics_recorded(study):
    session = study.plan(grid=_grid(trait_block=4)).run()
    seen = []
    session.progress = lambda m: seen.append(m.cells_done)
    cells = list(session.events())
    m = session.metrics
    assert m.cells_done == len(cells) == session.n_batches * session.n_trait_blocks
    assert seen == list(range(1, len(cells) + 1))
    s = m.summary()
    assert s["cells"] == s["live_cells"] == len(cells)
    assert s["replayed_cells"] == 0
    assert s["markers_per_s"] > 0 and s["trait_markers_per_s"] > 0
    assert s["wall_s"] > 0
    assert set(s["per_device"]) == {"serial"}
    assert s["per_device"]["serial"]["cells"] == len(cells)
    assert m.markers_done() == session.n_markers
    assert m.trait_markers_done() == session.n_markers * session.n_traits
    assert "cells" in m.progress_line()
    assert session.executor_info == {"kind": "serial", "devices": 1}


def test_session_metrics_separate_replayed_cells(study, tmp_path):
    ck = str(tmp_path / "ck")
    kw = dict(grid=_grid(trait_block=4), checkpoint_dir=ck)
    list(study.plan(**kw).run().events())
    session = study.plan(**kw).run()
    cells = list(session.events())
    assert all(c.replayed for c in cells)
    s = session.metrics.summary()
    assert s["live_cells"] == 0 and s["replayed_cells"] == len(cells)
    assert s["markers_per_s"] == 0.0   # replay costs np.load, not a device step


# ----------------------------------------------- out-of-order cell folding
#
# The executor's correctness spine: any completion order produces the same
# outputs.  The hypothesis property (tests/test_property.py) explores the
# space; these fixed cases run in environments without hypothesis and pin
# the tie-break rule the normalization exists for.


def test_best_trait_fold_is_completion_order_invariant():
    """Exact best-nlp ties across batches resolve to the LOWER global
    marker no matter which cell folds first — the serial result, made
    order-free."""
    from repro.core.sinks import BestTraitSink

    a = (np.asarray([2.5, 0.0, 3.0], np.float32), np.asarray([1, 0, 2], np.int32), 0)
    b = (np.asarray([2.5, 0.0, 1.0], np.float32), np.asarray([4, 0, 0], np.int32), 100)
    for order in ([a, b], [b, a]):
        sink = BestTraitSink(3)
        for best, row, lo in order:
            sink._fold(best, row, lo, 0)
        np.testing.assert_array_equal(sink.best_nlp, [2.5, 0.0, 3.0])
        # trait 0 ties at 2.5: marker 1 beats marker 104 in either order;
        # trait 1 never fires (stays -1); trait 2 is a plain max
        np.testing.assert_array_equal(sink.best_marker, [1, -1, 2])


def test_session_cells_fold_identically_in_any_order(study, source, cohort, tmp_path):
    """Replaying one session's committed cells through writers in shuffled
    orders produces byte-identical outputs (the multi-device completion
    order is one such shuffle)."""
    from repro.api.session import CheckpointReplay

    ck = str(tmp_path / "ck")
    session = study.plan(
        grid=_grid(trait_block=4), hit_threshold_nlp=1.0, checkpoint_dir=ck
    ).run()
    ref_dir = tmp_path / "ref"
    session.stream_to(TsvWriter(str(ref_dir)))
    files = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")
    ref = {f: (ref_dir / f).read_text() for f in files}

    replay = CheckpointReplay(
        ck, marker_ids=source.marker_ids, trait_names=study.trait_names
    )
    cells = list(replay.events())
    rng = np.random.default_rng(0)
    for trial in range(3):
        order = rng.permutation(len(cells))
        out = tmp_path / f"perm{trial}"
        w = TsvWriter(str(out))
        w.open(replay)
        for i in order:
            w.write(cells[i])
        w.close()
        assert {f: (out / f).read_text() for f in files} == ref


# ------------------------------- multi-device semantics (8 fake devices)


_CHILD = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import os.path as osp
    from repro.api import ExecSpec, GridSpec, LmmSpec, Study, TsvWriter
    from repro.core.association import AssocOptions
    from repro.io import open_genotypes, synth

    co = synth.make_cohort(n_samples=200, n_markers=400, n_traits=12,
                           n_causal=4, seed=5)
    d = tempfile.mkdtemp()
    beds = synth.write_split_plink(co, osp.join(d, "toy"), n_shards=3)
    src = open_genotypes(",".join(beds))
    study = Study.from_arrays(src, co.phenotypes, co.covariates)
    grid = GridSpec(batch_markers=128, block_m=64, block_n=128, block_p=4,
                    trait_block=4)
    FILES = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")

    def read(out):
        return {f: open(osp.join(out, f)).read() for f in FILES}

    def scan(tag, *, executor=None, checkpoint_dir=None, **plan_kw):
        session = study.plan(
            grid=grid, hit_threshold_nlp=2.0, executor=executor,
            checkpoint_dir=checkpoint_dir, **plan_kw,
        ).run()
        out = osp.join(d, tag)
        session.stream_to(TsvWriter(out))
        return read(out), session

    out = {}
    cases = {
        "dense": {},
        "dense_exact": {"options": AssocOptions(dof_mode="exact")},
        "fused": {"engine": "fused"},
        "lmm_loco": {"engine": "lmm", "lmm": LmmSpec(loco=True)},
    }
    for name, kw in cases.items():
        ref, _ = scan(f"{name}_serial", **kw)
        multi, session = scan(
            f"{name}_md",
            executor=ExecSpec(devices=3 if name != "fused" else 8), **kw,
        )
        out[f"{name}_identical"] = multi == ref
        info = session.executor_info
        out[f"{name}_workers"] = len(info["workers"])
        out[f"{name}_devices_used"] = len(
            session.metrics.summary()["per_device"]
        )
        if name == "dense":
            tm, _ = scan(f"{name}_tm", executor=ExecSpec(
                devices=4, placement="trait-major", lease_batches=1), **kw)
            out["dense_trait_major_identical"] = tm == ref
            stolen = sum(w["stolen_by"] for w in info["workers"].values())
            out["dense_steals"] = stolen  # informational; may be 0
            # forced-unpipelined worker (slot_prefetch=0, autotune off) is
            # the same bytes as both the serial walk and the pipelined run
            unp, _ = scan(f"{name}_unpiped", executor=ExecSpec(
                devices=3, slot_prefetch=0, autotune_lease=False), **kw)
            out["dense_unpipelined_identical"] = unp == ref
            out["dense_autotune"] = info["autotune"]
            out["dense_slot_prefetch"] = info["slot_prefetch"]
            md = session.metrics.summary()
            out["dense_per_device_decode"] = all(
                "decode_s" in v and "stage_s" in v
                for v in md["per_device"].values()
            )
            out["dense_decode_total"] = md["decode_s"]

    # Resume with a DIFFERENT device count: full 2-device checkpointed run,
    # cut one whole batch plus a mid-panel cell, resume on 4 devices.
    ck = osp.join(d, "ck")
    full, _ = scan("resume_full", executor=ExecSpec(devices=2),
                   checkpoint_dir=ck)
    mpath = osp.join(ck, "manifest.json")
    mani = json.load(open(mpath))
    lost = [k for k in mani["completed"] if k.startswith("1.")] + ["2.1"]
    for k in lost:
        mani["completed"].pop(k)
    json.dump(mani, open(mpath, "w"))
    resumed, session = scan(
        "resume_md",
        executor=ExecSpec(devices=4, placement="trait-major"),
        checkpoint_dir=ck,
    )
    out["resume_identical"] = resumed == full
    m = session.metrics.summary()
    out["resume_replayed"] = m["replayed_cells"]
    out["resume_live"] = m["live_cells"]
    out["resume_cells_total"] = session.n_batches * session.n_trait_blocks

    print(json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def child_results(tmp_path_factory):
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env = dict(os.environ, PYTHONPATH=src)
    # Scratch cwd under the test tmp tree: a child's relative writes must
    # never land in the repo checkout (see the conftest guard).
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        timeout=900, env=env, cwd=str(tmp_path_factory.mktemp("exec_child")),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("engine", ["dense", "dense_exact", "fused", "lmm_loco"])
def test_multi_device_bitwise_identical(child_results, engine):
    assert child_results[f"{engine}_identical"] is True
    assert child_results[f"{engine}_workers"] >= 2
    assert child_results[f"{engine}_devices_used"] >= 2


def test_trait_major_placement_bitwise_identical(child_results):
    assert child_results["dense_trait_major_identical"] is True


def test_unpipelined_fallback_bitwise_identical(child_results):
    """--slot-prefetch 0 (the historical one-staged-batch worker) and the
    pipelined default produce the same bytes — pipelining only moves WHEN
    host work happens, never what is computed."""
    assert child_results["dense_unpipelined_identical"] is True


def test_autotune_and_pipeline_reported(child_results):
    at = child_results["dense_autotune"]
    assert at["enabled"] is True
    assert at["initial_lease"] >= 1 and at["final_lease"] >= 1
    assert at["final_lease"] <= at["initial_lease"]  # tuner only shrinks
    assert at["adjustments"] >= 0
    assert child_results["dense_slot_prefetch"] == 1
    # decode/stage time is attributed per device in the metrics summary
    assert child_results["dense_per_device_decode"] is True
    assert child_results["dense_decode_total"] > 0


def test_resume_across_device_counts(child_results):
    assert child_results["resume_identical"] is True
    # the cut lost one whole batch (all its blocks) plus one mid-panel
    # cell: some cells replay, some recompute, every cell exactly once
    assert child_results["resume_replayed"] > 0
    assert child_results["resume_live"] > 0
    assert (
        child_results["resume_replayed"] + child_results["resume_live"]
        == child_results["resume_cells_total"]
    )
