"""Hypothesis property tests for the packed staging primitives
(DESIGN.md §17): pack -> device decode -> standardize round-trips bit for
bit, and the device tile repack equals the host repack, for arbitrary
hardcall matrices (ragged N, missing codes, degenerate markers)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.io.plink import pack_dosages
from repro.kernels.gwas_dot import ops as kops

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

_dosages = hnp.arrays(
    np.int8,
    st.tuples(st.integers(1, 10), st.integers(1, 70)),
    elements=st.sampled_from([-9, 0, 1, 2]),
)


@given(_dosages)
@settings(max_examples=40, deadline=None)
def test_pack_decode_standardize_roundtrip(d):
    """pack -> device decode -> standardize equals the straight float path
    bit for bit, for any hardcall matrix including ragged N and missing."""
    from repro.core.association import standardize_genotype_batch

    packed = pack_dosages(d)
    dev = np.asarray(kops.decode_packed_device(packed, n_samples=d.shape[1]))
    np.testing.assert_array_equal(dev, d.astype(np.float32))
    z_ref, ms_ref = standardize_genotype_batch(d.astype(np.float32))
    z_dev, ms_dev = standardize_genotype_batch(dev)
    np.testing.assert_array_equal(np.asarray(z_dev), np.asarray(z_ref))
    np.testing.assert_array_equal(np.asarray(ms_dev.maf), np.asarray(ms_ref.maf))
    # and the host LUT stats agree with the code-level reference
    stats_p = kops.marker_stats_from_packed(packed, d.shape[1])
    stats_c = kops.marker_stats_from_codes(
        kops.unpack_plink_to_codes(packed, d.shape[1])
    )
    for g, w in zip(stats_p, stats_c):
        np.testing.assert_array_equal(g, w)


@given(
    hnp.arrays(np.int8, st.tuples(st.integers(1, 8), st.integers(1, 50)),
               elements=st.sampled_from([-9, 0, 1, 2])),
    st.sampled_from([8, 16, 32]),
)
@settings(max_examples=40, deadline=None)
def test_device_repack_property(d, block_n):
    packed = pack_dosages(d)
    codes = kops.unpack_plink_to_codes(packed, d.shape[1])
    host = kops.pack_tiled(codes, block_n)
    dev = np.asarray(kops.repack_plink_tiled_device(
        packed, n_samples=d.shape[1], block_n=block_n, block_m=4,
    ))
    np.testing.assert_array_equal(dev[: d.shape[0]], host)


