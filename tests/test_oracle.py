"""Oracle conformance: the full ``GenomeScan`` pipeline, end to end, against
*independent* reference implementations.

Until now statistical correctness was asserted against our own modules; this
suite closes that loop:

  * OLS oracle  — per-(marker, trait) ordinary least squares in float64
                  numpy/scipy, both dof conventions, for the dense and fused
                  engines over single- and multi-file sources.
  * GLS oracle  — the mixed model checked against a naive generalized least
                  squares fit (explicit Cholesky whitening, nothing shared
                  with ``core.lmm``), including LOCO over a per-chromosome
                  fileset and both t/p epilogues.
  * Golden values — a handful of committed numbers from the seeded cohort so
                  silent drift (seed handling, standardization, dof) fails
                  loudly even if both implementations drift together.

Scans run with ``hit_threshold_nlp=0`` so the hit channel returns every
(marker, trait) cell — the comparison covers the full tile as produced by
the real engine/planner/sink pipeline, not a shortcut through the kernels.
"""
from __future__ import annotations

import os

import numpy as np
import pytest
from scipy import stats as sps

from repro.core.grm import grm_spectrum, stream_grm
from repro.core.lmm import fit_variance_components, reml_grid
from repro.core.screening import GenomeScan, ScanConfig
from repro.io import open_genotypes, plink, synth

# ------------------------------------------------------------------ fixtures


@pytest.fixture(scope="module")
def ols_cohort():
    # No missingness: the oracle would otherwise have to reproduce the
    # pipeline's mean-imputation instead of testing it.
    return synth.make_cohort(
        n_samples=180, n_markers=96, n_traits=5, n_covariates=2,
        n_causal=4, effect_size=0.6, missing_rate=0.0, seed=11,
    )


@pytest.fixture(scope="module")
def ols_paths(ols_cohort, tmp_path_factory):
    stem = str(tmp_path_factory.mktemp("oracle") / "ols")
    paths = synth.write_cohort_files(ols_cohort, stem)
    paths["split"] = synth.write_split_plink(ols_cohort, stem, n_shards=3)
    return paths


@pytest.fixture(scope="module")
def lmm_cohort():
    return synth.make_structured_cohort(
        n_samples=150, n_markers=110, n_traits=4, n_covariates=2,
        n_pops=2, fst=0.15, h2=0.4, n_causal=3, effect_size=0.5, seed=23,
    )


@pytest.fixture(scope="module")
def lmm_paths(lmm_cohort, tmp_path_factory):
    stem = str(tmp_path_factory.mktemp("oracle") / "lmm")
    paths = synth.write_cohort_files(lmm_cohort, stem)
    paths["split"] = synth.write_split_plink(lmm_cohort, stem, n_shards=3)
    return paths


def _full_stats(source, cohort, **cfg_kw):
    """Run the real pipeline, return dense (M, P) r/t/nlp arrays rebuilt
    from the hit channel (threshold 0 -> every cell) plus the ScanResult."""
    base = dict(batch_markers=32, hit_threshold_nlp=0.0,
                block_m=16, block_n=64, block_p=16)
    base.update(cfg_kw)
    res = GenomeScan(
        source, cohort.phenotypes, cohort.covariates, config=ScanConfig(**base)
    ).run()
    m, p = source.n_markers, cohort.phenotypes.shape[1]
    r = np.zeros((m, p), np.float64)
    t = np.zeros((m, p), np.float64)
    nlp = np.zeros((m, p), np.float64)
    for (mi, ti), (rv, tv, nv) in zip(res.hits, res.hit_stats):
        r[mi, ti], t[mi, ti], nlp[mi, ti] = rv, tv, nv
    return r, t, nlp, res


# ---------------------------------------------------------------- OLS oracle


def _ols_oracle(cohort, *, dof_mode):
    """Per-trait OLS in float64.  ``exact``: t of the genotype coefficient in
    ``y ~ 1 + C + g``.  ``paper``: correlation of standardized g with the
    covariate-residualized standardized y, dof = N - 2 (the published Eq. 3).
    Returns (r, t, neglog10p)."""
    g = cohort.dosages.astype(np.float64)
    n = g.shape[1]
    g_std = g - g.mean(axis=1, keepdims=True)
    g_std /= np.maximum(g_std.std(axis=1, keepdims=True), 1e-12)
    y = cohort.phenotypes.astype(np.float64)
    x = np.concatenate([np.ones((n, 1)), cohort.covariates.astype(np.float64)], axis=1)
    m, p = g.shape[0], y.shape[1]
    r = np.empty((m, p))
    t = np.empty((m, p))
    if dof_mode == "exact":
        dof = n - x.shape[1] - 1
        for mi in range(m):
            d = np.concatenate([x, g_std[mi][:, None]], axis=1)
            dtd_inv = np.linalg.inv(d.T @ d)
            beta = dtd_inv @ (d.T @ y)
            resid = y - d @ beta
            s2 = np.sum(resid * resid, axis=0) / dof
            t[mi] = beta[-1] / np.sqrt(s2 * dtd_inv[-1, -1])
        r[:] = t / np.sqrt(dof + t**2)
    else:
        dof = n - 2
        q, _ = np.linalg.qr(x)
        y_res = y - q @ (q.T @ y)
        y_res /= np.sqrt(np.mean(y_res**2, axis=0, keepdims=True))
        r[:] = g_std @ y_res / n
        t[:] = r * np.sqrt(dof / np.maximum(1.0 - r**2, 1e-12))
    nlp = -(sps.t.logsf(np.abs(t), dof) + np.log(2.0)) / np.log(10.0)
    return r, t, nlp


@pytest.mark.parametrize("dof_mode", ["paper", "exact"])
def test_dense_engine_matches_ols_oracle(ols_cohort, ols_paths, dof_mode):
    from repro.core.association import AssocOptions

    src = plink.PlinkBed(ols_paths["bed"])
    r, t, nlp, res = _full_stats(
        src, ols_cohort, engine="dense", options=AssocOptions(dof_mode=dof_mode)
    )
    r_o, t_o, nlp_o = _ols_oracle(ols_cohort, dof_mode=dof_mode)
    np.testing.assert_allclose(r, r_o, atol=2e-5)
    np.testing.assert_allclose(t, t_o, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(nlp, nlp_o, rtol=2e-3, atol=5e-3)
    # beta on the standardized scale IS r (unit-variance regressor/response)
    assert res.dof == (180 - 2 if dof_mode == "paper" else 180 - 4)


@pytest.mark.parametrize("split", [False, True], ids=["single-file", "multi-file"])
def test_fused_engine_matches_ols_oracle(ols_cohort, ols_paths, split):
    src = (
        open_genotypes(",".join(ols_paths["split"]))
        if split else plink.PlinkBed(ols_paths["bed"])
    )
    r, t, nlp, _ = _full_stats(src, ols_cohort, engine="fused")
    r_o, t_o, nlp_o = _ols_oracle(ols_cohort, dof_mode="paper")
    np.testing.assert_allclose(r, r_o, atol=5e-5)
    np.testing.assert_allclose(t, t_o, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(nlp, nlp_o, rtol=5e-3, atol=1e-2)


def test_dense_multifile_equals_single(ols_cohort, ols_paths):
    """Same cohort through a ragged per-chromosome fileset: identical cells.
    The ragged shards change batch shapes, hence GEMM tiling, so equality is
    to float32 reduction-order tolerance, not bitwise (the bitwise guarantee
    for *identical* decompositions lives in tests/test_multifile.py)."""
    single = _full_stats(plink.PlinkBed(ols_paths["bed"]), ols_cohort, engine="dense")
    multi = _full_stats(open_genotypes(",".join(ols_paths["split"])), ols_cohort, engine="dense")
    np.testing.assert_allclose(single[1], multi[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(single[2], multi[2], rtol=1e-5, atol=1e-5)


def test_golden_values_dense_paper(ols_cohort, ols_paths):
    src = plink.PlinkBed(ols_paths["bed"])
    _, _, _, res = _full_stats(src, ols_cohort, engine="dense")
    got = np.asarray(res.best_nlp, np.float64)
    expected = np.asarray(GOLDEN["dense_paper_best_nlp"])
    np.testing.assert_allclose(got, expected, rtol=1e-3, atol=1e-3)
    assert res.lambda_gc == pytest.approx(GOLDEN["dense_paper_lambda_gc"], abs=0.02)


def test_fused_bf16_epilogue_audit(ols_cohort, ols_paths):
    """bf16 fused-engine audit (ROADMAP item): run the fused engine end to
    end with ``input_dtype="bf16"`` against the float64 OLS oracle and pin
    the per-stage precision split — the GEMM may round at bfloat16 (±2^-8
    relative on r), but the epilogue (t, -log10 p, argmax) must stay fp32.

    Documented tolerances (empirical on this 180-sample cohort, with ~3x
    headroom): |Δr| <= 2e-3 absolute vs the oracle; t within 5e-2; nlp
    within 1.5e-1.  The epilogue split is asserted structurally: t
    recomputed in float64 *from the engine's own bf16-GEMM r* matches the
    engine's t to ~1e-5 — i.e. all bf16 error enters through the GEMM, none
    through the epilogue."""
    src = plink.PlinkBed(ols_paths["bed"])
    r, t, nlp, res = _full_stats(src, ols_cohort, engine="fused", input_dtype="bf16")
    r_o, t_o, nlp_o = _ols_oracle(ols_cohort, dof_mode="paper")
    np.testing.assert_allclose(r, r_o, atol=2e-3)
    np.testing.assert_allclose(t, t_o, atol=5e-2)
    np.testing.assert_allclose(nlp, nlp_o, atol=1.5e-1)
    # GEMM-bf16 / epilogue-fp32 split: Eq. 3 in float64 from the engine's r.
    dof = 180 - 2
    t_from_r = np.clip(r, -1, 1) * np.sqrt(dof / np.maximum(1.0 - r**2, 1e-12))
    np.testing.assert_allclose(t, t_from_r, atol=1e-4)
    # ... and bf16 must actually have engaged (the GEMM differs from fp32).
    r32, _, _, _ = _full_stats(src, ols_cohort, engine="fused")
    assert np.abs(r - r32).max() > 1e-6, "bf16 input dtype did not reach the kernel"
    # ranking survives: the per-trait argmax marker is unchanged
    fp32_res = GenomeScan(
        src, ols_cohort.phenotypes, ols_cohort.covariates,
        config=ScanConfig(batch_markers=32, hit_threshold_nlp=0.0,
                          block_m=16, block_n=64, block_p=16, engine="fused"),
    ).run()
    np.testing.assert_array_equal(res.best_marker, fp32_res.best_marker)


# ---------------------------------------------------------------- GLS oracle


def _gls_oracle(cohort, k_of_marker, delta, *, shard_of=None):
    """Naive mixed-model oracle: explicit V = K + delta*I per scope,
    Cholesky whiten, per-cell OLS on the whitened design.  Shares no code
    with core.lmm (numpy only, materialized V)."""
    g = cohort.dosages.astype(np.float64)
    m, n = g.shape
    g_std = g - g.mean(axis=1, keepdims=True)
    g_std /= np.maximum(g_std.std(axis=1, keepdims=True), 1e-12)
    y = cohort.phenotypes.astype(np.float64)
    x = np.concatenate([np.ones((n, 1)), cohort.covariates.astype(np.float64)], axis=1)
    p = y.shape[1]
    t = np.empty((m, p))
    linv_cache: dict[int, np.ndarray] = {}
    for mi in range(m):
        sid = 0 if shard_of is None else shard_of(mi)
        if sid not in linv_cache:
            v = k_of_marker(mi) + delta * np.eye(n)
            linv_cache[sid] = np.linalg.inv(np.linalg.cholesky(v))
        linv = linv_cache[sid]
        d = linv @ np.concatenate([x, g_std[mi][:, None]], axis=1)
        yw = linv @ y
        dtd_inv = np.linalg.inv(d.T @ d)
        beta = dtd_inv @ (d.T @ yw)
        resid = yw - d @ beta
        s2 = np.sum(resid * resid, axis=0) / (n - d.shape[1])
        t[mi] = beta[-1] / np.sqrt(s2 * dtd_inv[-1, -1])
    dof = n - x.shape[1] - 1
    nlp = -(sps.t.logsf(np.abs(t), dof) + np.log(2.0)) / np.log(10.0)
    return t, nlp


@pytest.mark.parametrize("epilogue", ["dense", "fused"])
def test_lmm_matches_naive_gls(lmm_cohort, lmm_paths, epilogue):
    src = plink.PlinkBed(lmm_paths["bed"])
    delta = 1.5  # pinned: this test isolates the linear algebra from REML
    _, t, nlp, res = _full_stats(
        src, lmm_cohort, engine="lmm", lmm_delta=delta, lmm_epilogue=epilogue,
    )
    grm = stream_grm(src, batch_markers=32)
    k_full = grm.full()
    t_o, nlp_o = _gls_oracle(lmm_cohort, lambda mi: k_full, delta)
    np.testing.assert_allclose(t, t_o, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(nlp, nlp_o, rtol=5e-3, atol=1e-2)
    assert res.dof == 150 - 2 - 2


def test_lmm_loco_multifile_matches_naive_gls(lmm_cohort, lmm_paths):
    src = open_genotypes(",".join(lmm_paths["split"]))
    assert src.n_shards == 3
    delta = 1.5
    _, t, nlp, res = _full_stats(
        src, lmm_cohort, engine="lmm", loco=True, lmm_delta=delta,
    )
    grm = stream_grm(src, batch_markers=32)
    bounds = np.asarray(src.shard_boundaries)

    def shard_of(mi):
        return int(np.searchsorted(bounds, mi, side="right")) - 1

    t_o, nlp_o = _gls_oracle(
        lmm_cohort, lambda mi: grm.loco(shard_of(mi)), delta, shard_of=shard_of
    )
    np.testing.assert_allclose(t, t_o, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(nlp, nlp_o, rtol=5e-3, atol=1e-2)
    assert res.lmm_info["scopes"] == 3
    assert res.lmm_info["loco"] is True


def test_lmm_fused_epilogue_bitwise_close(lmm_cohort, lmm_paths):
    src = plink.PlinkBed(lmm_paths["bed"])
    out = {}
    for epi in ("dense", "fused"):
        _, t, nlp, _ = _full_stats(
            src, lmm_cohort, engine="lmm", lmm_delta=1.0, lmm_epilogue=epi
        )
        out[epi] = (t, nlp)
    np.testing.assert_allclose(out["dense"][0], out["fused"][0], atol=1e-4)
    np.testing.assert_allclose(out["dense"][1], out["fused"][1], atol=1e-3)


def test_lmm_calibrates_where_ols_inflates(tmp_path):
    """The reason the wing exists: on a structured cohort with a polygenic
    background and NO fixed effects, the OLS scan's genomic-control lambda
    inflates while the mixed model stays near 1."""
    co = synth.make_structured_cohort(
        n_samples=150, n_markers=150, n_traits=3, n_pops=2, fst=0.2,
        h2=0.5, n_causal=0, seed=31,
    )
    paths = synth.write_cohort_files(co, str(tmp_path / "cal"))
    src = plink.PlinkBed(paths["bed"])
    lam = {}
    for engine in ("dense", "lmm"):
        cfg = ScanConfig(batch_markers=64, engine=engine, block_m=16, block_p=16)
        lam[engine] = GenomeScan(src, co.phenotypes, co.covariates, config=cfg).run().lambda_gc
    assert lam["dense"] > 1.25, f"structured cohort should inflate OLS: {lam}"
    assert 0.7 < lam["lmm"] < 1.25, f"LMM should calibrate: {lam}"


def test_lmm_reml_recovers_heritability(lmm_cohort, lmm_paths):
    """REML point estimates on the rotated panel recover the planted h2 to
    within the (wide) tolerance a 150-sample cohort supports."""
    src = plink.PlinkBed(lmm_paths["bed"])
    _, _, _, res = _full_stats(src, lmm_cohort, engine="lmm")
    h2 = np.asarray(res.lmm_info["h2"])
    assert h2.shape == (4,)
    assert 0.05 < float(h2.mean()) < 0.85
    assert abs(float(h2.mean()) - lmm_cohort.h2) < 0.35


def test_reml_profile_matches_dense_formulation(lmm_cohort, lmm_paths):
    """The rotated-space REML profile must equal the textbook dense REML
    (explicit V, slogdet) up to a delta-independent constant."""
    src = plink.PlinkBed(lmm_paths["bed"])
    grm = stream_grm(src, batch_markers=32)
    k = grm.full()
    s, u = grm_spectrum(k)
    n = k.shape[0]
    y = lmm_cohort.phenotypes[:, :2].astype(np.float64)
    x = np.concatenate(
        [np.ones((n, 1)), lmm_cohort.covariates.astype(np.float64)], axis=1
    )
    deltas = np.array([0.3, 1.0, 3.0])
    ll_rot = reml_grid(u.T @ y, u.T @ x, s, deltas)

    def dense_reml(d, yt):
        v = k + d * np.eye(n)
        vinv = np.linalg.inv(v)
        xtvx = x.T @ vinv @ x
        beta = np.linalg.solve(xtvx, x.T @ vinv @ yt)
        resid = yt - x @ beta
        nk = n - x.shape[1]
        s2 = float(resid @ vinv @ resid) / nk
        return -0.5 * (
            nk * (np.log(2 * np.pi * s2) + 1.0)
            + np.linalg.slogdet(v)[1]
            + np.linalg.slogdet(xtvx)[1]
        )

    for t in range(2):
        ll_dense = np.array([dense_reml(d, y[:, t]) for d in deltas])
        np.testing.assert_allclose(ll_rot[:, t], ll_dense, rtol=1e-8, atol=1e-6)


def test_streamed_grm_matches_naive(lmm_cohort, lmm_paths):
    """One-pass streamed accumulation == materialized numpy GRM, and the
    LOCO identity holds: loco(s) excludes exactly shard s's contribution."""
    src = open_genotypes(",".join(lmm_paths["split"]))
    grm = stream_grm(src, batch_markers=32)
    g = lmm_cohort.dosages.astype(np.float64)
    z = g - g.mean(axis=1, keepdims=True)
    z /= np.maximum(g.std(axis=1), 1e-12)[:, None]
    naive = z.T @ z / g.shape[0]
    np.testing.assert_allclose(grm.full(), naive, atol=1e-4)
    bounds = src.shard_boundaries
    for sid in range(3):
        rows = np.ones(g.shape[0], bool)
        rows[bounds[sid]: bounds[sid + 1]] = False
        naive_loco = z[rows].T @ z[rows] / rows.sum()
        np.testing.assert_allclose(grm.loco(sid), naive_loco, atol=1e-4)


def test_lmm_checkpoint_fingerprint_guards_grm(lmm_cohort, lmm_paths, tmp_path):
    """Resuming a mixed-model scan against different variance components
    (hence a different rotation) must be refused, not silently merged."""
    src = plink.PlinkBed(lmm_paths["bed"])
    ck = str(tmp_path / "ck")
    cfg = dict(batch_markers=64, engine="lmm", block_m=16, block_p=16)
    r1 = GenomeScan(
        src, lmm_cohort.phenotypes, lmm_cohort.covariates,
        config=ScanConfig(checkpoint_dir=ck, lmm_delta=1.0, **cfg),
    ).run()
    # identical scan resumes cleanly from the completed checkpoint
    r2 = GenomeScan(
        src, lmm_cohort.phenotypes, lmm_cohort.covariates,
        config=ScanConfig(checkpoint_dir=ck, lmm_delta=1.0, **cfg),
    ).run()
    np.testing.assert_array_equal(r1.best_nlp, r2.best_nlp)
    np.testing.assert_array_equal(r1.hits, r2.hits)
    with pytest.raises(ValueError, match="different scan"):
        GenomeScan(
            src, lmm_cohort.phenotypes, lmm_cohort.covariates,
            config=ScanConfig(checkpoint_dir=ck, lmm_delta=2.0, **cfg),
        ).run()


def test_lmm_validates_unsupported_combos(lmm_cohort, lmm_paths):
    src = plink.PlinkBed(lmm_paths["bed"])
    with pytest.raises(ValueError, match="sharding"):
        GenomeScan(src, lmm_cohort.phenotypes, None,
                   config=ScanConfig(engine="lmm", mode="sample"))
    with pytest.raises(ValueError, match="multivariate"):
        GenomeScan(src, lmm_cohort.phenotypes, None,
                   config=ScanConfig(engine="lmm", multivariate=True))
    with pytest.raises(ValueError, match="fileset"):
        GenomeScan(src, lmm_cohort.phenotypes, None,
                   config=ScanConfig(engine="lmm", loco=True))


# Committed golden values for the seeded (seed=11) cohort, dense engine,
# paper dof.  Regenerate by rerunning the scan in test_golden_values_dense_
# paper if the *synthesis* recipe changes deliberately; drift for any other
# reason is exactly the bug this guard exists to catch.
GOLDEN = {
    "dense_paper_best_nlp": [14.1459, 11.6955, 13.1648, 11.9401, 1.8614],
    "dense_paper_lambda_gc": 1.2895,
}
