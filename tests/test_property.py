"""Property-based tests (hypothesis) on the system's core invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import association as A
from repro.core import stats as S
from repro.core.residualize import covariate_basis, residualize_and_standardize
from repro.io.plink import decode_packed, pack_dosages
from repro.kernels.gwas_dot import ops

_dosages = hnp.arrays(
    np.int8,
    st.tuples(st.integers(1, 12), st.integers(4, 64)),
    elements=st.sampled_from([-9, 0, 1, 2]),
)


@given(_dosages)
@settings(max_examples=40, deadline=None)
def test_plink_pack_roundtrip(d):
    np.testing.assert_array_equal(decode_packed(pack_dosages(d), d.shape[1]), d)


@given(
    hnp.arrays(np.uint8, st.tuples(st.integers(1, 8), st.integers(4, 96)),
               elements=st.integers(0, 3)),
    st.sampled_from([4, 8, 16]),
)
@settings(max_examples=40, deadline=None)
def test_pack_tiled_padding_invariant(codes, quarter_block):
    bn = quarter_block * 4
    packed = ops.pack_tiled(codes, bn)
    n_pad = packed.shape[1] * 4
    assert n_pad % bn == 0
    # unpack by construction: slot s of byte b in tile t = sample t*bn + s*bn/4 + b
    m = codes.shape[0]
    tiles = packed.reshape(m, -1, bn // 4)
    for s in range(4):
        part = (tiles >> (2 * s)) & 0b11
        for t in range(tiles.shape[1]):
            for b_ in range(bn // 4):
                sample = t * bn + s * (bn // 4) + b_
                if sample < codes.shape[1]:
                    assert part[0, t, b_] == codes[0, sample]
    # padded samples carry the missing code
    flat = np.concatenate([((tiles >> (2 * s)) & 3) for s in range(4)], axis=-1)


@given(st.integers(10, 500), st.floats(0.1, 100.0))
@settings(max_examples=60, deadline=None)
def test_pvalue_in_unit_range(n, t):
    nlp = float(S.neglog10_p_from_t(jnp.float32(t), float(n)))
    assert nlp >= 0.0 and np.isfinite(nlp)


@given(
    hnp.arrays(np.float32, st.tuples(st.integers(20, 60), st.integers(2, 6)),
               elements=st.floats(-3, 3, width=32)),
    st.floats(0.1, 10.0),
    st.floats(-5.0, 5.0),
)
@settings(max_examples=25, deadline=None)
def test_association_scale_shift_invariance(y, scale, shift):
    """r/t are invariant to affine transforms of each phenotype.

    Columns whose spread is at float32 cancellation scale relative to the
    shift are excluded: invariance cannot hold numerically there (hypothesis
    found the boundary — e.g. std 1e-4 with shift 5 leaves ~2 significant
    digits after mean subtraction)."""
    n = y.shape[0]
    rng = np.random.default_rng(0)
    g = rng.integers(0, 3, size=(4, n)).astype(np.float32)
    if np.any(g.std(axis=1) < 1e-6):
        g[:, 0] += 1  # ensure polymorphic
    qb = covariate_basis(None, n)
    p1 = residualize_and_standardize(jnp.asarray(y), qb)
    p2 = residualize_and_standardize(jnp.asarray(y * scale + shift), qb)
    r1, _ = A.assoc_batch(jnp.asarray(g), p1.y, n_samples=n, n_covariates=0)
    r2, _ = A.assoc_batch(jnp.asarray(g), p2.y, n_samples=n, n_covariates=0)
    well_scaled = y.std(axis=0) * abs(scale) > 1e-3 * (1.0 + abs(shift) + np.abs(y).max())
    valid = np.asarray(p1.valid) & np.asarray(p2.valid) & well_scaled
    np.testing.assert_allclose(
        np.asarray(r1.r)[:, valid], np.asarray(r2.r)[:, valid], atol=5e-4
    )


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_association_sample_permutation_equivariance(seed):
    """Permuting samples consistently in G and Y leaves statistics unchanged."""
    rng = np.random.default_rng(seed)
    n = 64
    g = rng.integers(0, 3, size=(6, n)).astype(np.float32)
    y = rng.normal(size=(n, 3)).astype(np.float32)
    perm = rng.permutation(n)
    qb = covariate_basis(None, n)
    p1 = residualize_and_standardize(jnp.asarray(y), qb)
    p2 = residualize_and_standardize(jnp.asarray(y[perm]), qb)
    r1, _ = A.assoc_batch(jnp.asarray(g), p1.y, n_samples=n, n_covariates=0)
    r2, _ = A.assoc_batch(jnp.asarray(g[:, perm]), p2.y, n_samples=n, n_covariates=0)
    np.testing.assert_allclose(np.asarray(r1.r), np.asarray(r2.r), atol=2e-4)


@given(hnp.arrays(np.float32, st.integers(2, 200),
                  elements=st.floats(0, 50, width=32)))
@settings(max_examples=30, deadline=None)
def test_bh_qvalues_monotone_and_bounded(nlp):
    nlq = np.asarray(S.bh_qvalues(jnp.asarray(nlp)))
    assert np.all(nlq >= -1e-6)
    assert np.all(nlq <= nlp + 1e-4)  # q >= p always
    # order-preserving: stronger p -> stronger q
    order_p = np.argsort(-nlp, kind="stable")
    q_sorted = nlq[order_p]
    assert np.all(np.diff(q_sorted) <= 1e-5)


@given(st.integers(1, 6), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_correlation_bounded(m_markers, p_traits):
    rng = np.random.default_rng(m_markers * 31 + p_traits)
    n = 48
    g = rng.integers(0, 3, size=(m_markers, n)).astype(np.float32)
    y = rng.normal(size=(n, p_traits)).astype(np.float32)
    qb = covariate_basis(None, n)
    panel = residualize_and_standardize(jnp.asarray(y), qb)
    res, _ = A.assoc_batch(jnp.asarray(g), panel.y, n_samples=n, n_covariates=0)
    assert np.all(np.abs(np.asarray(res.r)) <= 1.0 + 1e-6)
