"""Property-based tests (hypothesis) on the system's core invariants."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import association as A
from repro.core import stats as S
from repro.core.residualize import covariate_basis, residualize_and_standardize
from repro.io.plink import decode_packed, pack_dosages
from repro.kernels.gwas_dot import ops

_dosages = hnp.arrays(
    np.int8,
    st.tuples(st.integers(1, 12), st.integers(4, 64)),
    elements=st.sampled_from([-9, 0, 1, 2]),
)


@given(_dosages)
@settings(max_examples=40, deadline=None)
def test_plink_pack_roundtrip(d):
    np.testing.assert_array_equal(decode_packed(pack_dosages(d), d.shape[1]), d)


@given(
    hnp.arrays(np.uint8, st.tuples(st.integers(1, 8), st.integers(4, 96)),
               elements=st.integers(0, 3)),
    st.sampled_from([4, 8, 16]),
)
@settings(max_examples=40, deadline=None)
def test_pack_tiled_padding_invariant(codes, quarter_block):
    bn = quarter_block * 4
    packed = ops.pack_tiled(codes, bn)
    n_pad = packed.shape[1] * 4
    assert n_pad % bn == 0
    # unpack by construction: slot s of byte b in tile t = sample t*bn + s*bn/4 + b
    m = codes.shape[0]
    tiles = packed.reshape(m, -1, bn // 4)
    for s in range(4):
        part = (tiles >> (2 * s)) & 0b11
        for t in range(tiles.shape[1]):
            for b_ in range(bn // 4):
                sample = t * bn + s * (bn // 4) + b_
                if sample < codes.shape[1]:
                    assert part[0, t, b_] == codes[0, sample]
    # padded samples carry the missing code
    flat = np.concatenate([((tiles >> (2 * s)) & 3) for s in range(4)], axis=-1)


@given(st.integers(10, 500), st.floats(0.1, 100.0))
@settings(max_examples=60, deadline=None)
def test_pvalue_in_unit_range(n, t):
    nlp = float(S.neglog10_p_from_t(jnp.float32(t), float(n)))
    assert nlp >= 0.0 and np.isfinite(nlp)


@given(
    hnp.arrays(np.float32, st.tuples(st.integers(20, 60), st.integers(2, 6)),
               elements=st.floats(-3, 3, width=32)),
    st.floats(0.1, 10.0),
    st.floats(-5.0, 5.0),
)
@settings(max_examples=25, deadline=None)
def test_association_scale_shift_invariance(y, scale, shift):
    """r/t are invariant to affine transforms of each phenotype.

    Columns whose spread is at float32 cancellation scale relative to the
    shift are excluded: invariance cannot hold numerically there (hypothesis
    found the boundary — e.g. std 1e-4 with shift 5 leaves ~2 significant
    digits after mean subtraction)."""
    n = y.shape[0]
    rng = np.random.default_rng(0)
    g = rng.integers(0, 3, size=(4, n)).astype(np.float32)
    if np.any(g.std(axis=1) < 1e-6):
        g[:, 0] += 1  # ensure polymorphic
    qb = covariate_basis(None, n)
    p1 = residualize_and_standardize(jnp.asarray(y), qb)
    p2 = residualize_and_standardize(jnp.asarray(y * scale + shift), qb)
    r1, _ = A.assoc_batch(jnp.asarray(g), p1.y, n_samples=n, n_covariates=0)
    r2, _ = A.assoc_batch(jnp.asarray(g), p2.y, n_samples=n, n_covariates=0)
    well_scaled = y.std(axis=0) * abs(scale) > 1e-3 * (1.0 + abs(shift) + np.abs(y).max())
    valid = np.asarray(p1.valid) & np.asarray(p2.valid) & well_scaled
    np.testing.assert_allclose(
        np.asarray(r1.r)[:, valid], np.asarray(r2.r)[:, valid], atol=5e-4
    )


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_association_sample_permutation_equivariance(seed):
    """Permuting samples consistently in G and Y leaves statistics unchanged."""
    rng = np.random.default_rng(seed)
    n = 64
    g = rng.integers(0, 3, size=(6, n)).astype(np.float32)
    y = rng.normal(size=(n, 3)).astype(np.float32)
    perm = rng.permutation(n)
    qb = covariate_basis(None, n)
    p1 = residualize_and_standardize(jnp.asarray(y), qb)
    p2 = residualize_and_standardize(jnp.asarray(y[perm]), qb)
    r1, _ = A.assoc_batch(jnp.asarray(g), p1.y, n_samples=n, n_covariates=0)
    r2, _ = A.assoc_batch(jnp.asarray(g[:, perm]), p2.y, n_samples=n, n_covariates=0)
    np.testing.assert_allclose(np.asarray(r1.r), np.asarray(r2.r), atol=2e-4)


@given(hnp.arrays(np.float32, st.integers(2, 200),
                  elements=st.floats(0, 50, width=32)))
@settings(max_examples=30, deadline=None)
def test_bh_qvalues_monotone_and_bounded(nlp):
    nlq = np.asarray(S.bh_qvalues(jnp.asarray(nlp)))
    assert np.all(nlq >= -1e-6)
    assert np.all(nlq <= nlp + 1e-4)  # q >= p always
    # order-preserving: stronger p -> stronger q
    order_p = np.argsort(-nlp, kind="stable")
    q_sorted = nlq[order_p]
    assert np.all(np.diff(q_sorted) <= 1e-5)


# ----------------------------------------------------- shard-merge folding
#
# Checkpoint-resume silently relies on one invariant: folding per-batch sink
# payloads (committed shards) through ``merge_shard`` must reproduce exactly
# what a single uninterrupted pass over the same marker stream accumulates.
# These properties split a stream at arbitrary boundaries chosen by
# hypothesis and assert the fold is bitwise-identical.


def _sink_stream(seed: int, m: int, p: int):
    """Deterministic synthetic device-step outputs with all-distinct nlp
    values (distinctness makes the argmax/fold tie-free, so bitwise equality
    is the correct expectation)."""
    rng = np.random.default_rng(seed)
    nlp = (rng.permutation(m * p).astype(np.float32) * 0.37).reshape(m, p)
    r = np.tanh(rng.normal(size=(m, p))).astype(np.float32)
    t = rng.normal(scale=3.0, size=(m, p)).astype(np.float32)
    maf = rng.uniform(0.0, 0.5, size=m).astype(np.float32)
    valid = rng.random(m) > 0.1
    return nlp, r, t, maf, valid


def _batch_view(arrays, lo: int, hi: int, index: int, n_traits: int, threshold: float):
    """A BatchView over host arrays shaped exactly like one device output."""
    from repro.core.engines import HostBatch
    from repro.core.sinks import BatchView
    from repro.runtime.prefetch import MarkerBatch

    nlp, r, t, maf, valid = arrays
    sub = nlp[lo:hi]
    out = {
        "nlp": sub,
        "r": r[lo:hi],
        "t": t[lo:hi],
        "maf": maf[lo:hi],
        "valid": valid[lo:hi],
        "batch_best_nlp": sub.max(axis=0),
        "batch_best_row": sub.argmax(axis=0).astype(np.int32),
        "hit_count": np.int32((sub >= threshold).sum()),
    }
    batch = MarkerBatch(index=index, lo=lo, hi=hi, source_id=0, local_lo=lo, local_hi=hi)
    return BatchView(HostBatch(batch, ()), out, n_traits)


def _make_sinks(m: int, p: int, threshold: float):
    from repro.core.sinks import BestTraitSink, HitSink, LambdaGCSink, QCSink

    return [BestTraitSink(p), HitSink(threshold), QCSink(m), LambdaGCSink(rows=16)]


def _results(sinks):
    out = {}
    for s in sinks:
        out.update(s.result())
    return out


_stream_split = st.tuples(
    st.integers(0, 2**31 - 1),       # stream seed
    st.integers(4, 72),              # markers
    st.integers(1, 5),               # traits
    st.floats(0.0, 1.0),             # hit-threshold quantile
    st.lists(st.integers(1, 71), max_size=6, unique=True),  # cut points
)


@given(_stream_split)
@settings(max_examples=30, deadline=None)
def test_shard_fold_equals_single_pass(case):
    """Split at arbitrary batch boundaries; committing each piece's payload
    and folding the shards == one uninterrupted pass.  Bitwise."""
    seed, m, p, q, raw_cuts = case
    arrays = _sink_stream(seed, m, p)
    threshold = float(np.quantile(arrays[0], q))
    cuts = sorted({c for c in raw_cuts if c < m})
    bounds = [0, *cuts, m]

    # uninterrupted run: every piece consumed live via on_batch, committing
    # its payload shard along the way (exactly what CheckpointSink persists)
    shards = []
    writer = _make_sinks(m, p, threshold)
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        pay: dict = {}
        v = _batch_view(arrays, lo, hi, i, p, threshold)
        for s in writer:
            s.on_batch(v, pay)
        shards.append((pay, lo, hi))

    # resumed run: fresh sinks see only the committed shards
    merged = _make_sinks(m, p, threshold)
    for pay, lo, hi in shards:
        for s in merged:
            s.merge_shard(pay, lo, hi)

    rw, rm = _results(writer), _results(merged)
    np.testing.assert_array_equal(rw["best_nlp"], rm["best_nlp"])
    np.testing.assert_array_equal(rw["best_marker"], rm["best_marker"])
    np.testing.assert_array_equal(rw["hits"], rm["hits"])
    np.testing.assert_array_equal(rw["hit_stats"], rm["hit_stats"])
    np.testing.assert_array_equal(rw["maf"], rm["maf"])
    np.testing.assert_array_equal(rw["valid"], rm["valid"])
    assert rw["lambda_gc"] == rm["lambda_gc"]

    # and the decomposition-independent outputs match a one-batch pass
    # (lambda_gc legitimately depends on the probe decomposition, so it is
    # excluded here — the probe is a per-batch subsample by design)
    single = _make_sinks(m, p, threshold)
    pay_all: dict = {}
    view = _batch_view(arrays, 0, m, 0, p, threshold)
    for s in single:
        s.on_batch(view, pay_all)
    rs = _results(single)
    np.testing.assert_array_equal(rs["best_nlp"], rm["best_nlp"])
    np.testing.assert_array_equal(rs["best_marker"], rm["best_marker"])
    np.testing.assert_array_equal(rs["hits"], rm["hits"])
    np.testing.assert_array_equal(rs["hit_stats"], rm["hit_stats"])
    np.testing.assert_array_equal(rs["maf"], rm["maf"])


@given(_stream_split, st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_shard_fold_is_order_insensitive(case, perm_seed):
    """Resume folds freshly-computed batches before replayed shards, so the
    fold must not depend on shard arrival order (up to hit row order, which
    is canonicalized by sorting)."""
    seed, m, p, q, raw_cuts = case
    arrays = _sink_stream(seed, m, p)
    threshold = float(np.quantile(arrays[0], q))
    cuts = sorted({c for c in raw_cuts if c < m})
    bounds = [0, *cuts, m]
    shards = []
    writer = _make_sinks(m, p, threshold)
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        pay: dict = {}
        v = _batch_view(arrays, lo, hi, i, p, threshold)
        for s in writer:
            s.on_batch(v, pay)
        shards.append((pay, lo, hi))

    results = []
    for order in (range(len(shards)), np.random.default_rng(perm_seed).permutation(len(shards))):
        merged = _make_sinks(m, p, threshold)
        for i in order:
            pay, lo, hi = shards[i]
            for s in merged:
                s.merge_shard(pay, lo, hi)
        results.append(_results(merged))
    a, b = results
    np.testing.assert_array_equal(a["best_nlp"], b["best_nlp"])
    np.testing.assert_array_equal(a["best_marker"], b["best_marker"])
    oa, ob = np.lexsort(a["hits"].T), np.lexsort(b["hits"].T)
    np.testing.assert_array_equal(a["hits"][oa], b["hits"][ob])
    np.testing.assert_array_equal(a["hit_stats"][oa], b["hit_stats"][ob])
    np.testing.assert_array_equal(a["maf"], b["maf"])
    assert a["lambda_gc"] == b["lambda_gc"]


@given(_stream_split)
@settings(max_examples=15, deadline=None)
def test_shard_fold_survives_npz_roundtrip(case):
    """Shards travel through ``np.savez`` on the real resume path; the
    round trip must not perturb a single bit of the fold."""
    import io as _io

    seed, m, p, q, raw_cuts = case
    arrays = _sink_stream(seed, m, p)
    threshold = float(np.quantile(arrays[0], q))
    cuts = sorted({c for c in raw_cuts if c < m})
    bounds = [0, *cuts, m]
    direct = _make_sinks(m, p, threshold)
    rehydrated = _make_sinks(m, p, threshold)
    writer = _make_sinks(m, p, threshold)
    for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        pay: dict = {}
        v = _batch_view(arrays, lo, hi, i, p, threshold)
        for s in writer:
            s.on_batch(v, pay)
        for s in direct:
            s.merge_shard(pay, lo, hi)
        buf = _io.BytesIO()
        np.savez(buf, **pay)
        buf.seek(0)
        with np.load(buf) as z:
            pay2 = {k: z[k] for k in z.files}
        for s in rehydrated:
            s.merge_shard(pay2, lo, hi)
    a, b = _results(direct), _results(rehydrated)
    np.testing.assert_array_equal(a["best_nlp"], b["best_nlp"])
    np.testing.assert_array_equal(a["best_marker"], b["best_marker"])
    np.testing.assert_array_equal(a["hits"], b["hits"])
    np.testing.assert_array_equal(a["hit_stats"], b["hit_stats"])
    assert a["lambda_gc"] == b["lambda_gc"]


# ------------------------------------------------- 2-D grid cell folding
#
# The blocked scan (DESIGN.md §10) folds (marker-batch x trait-block) grid
# cells instead of whole batches.  Blocks partition the trait axis, so the
# order cells are folded in — the driver's marker-major order, a resume's
# replay order, anything — must never change what the sinks accumulate.


def _cell_view(arrays, lo, hi, t_lo, t_hi, index, block_index, threshold):
    """A BatchView over one (marker, trait-block) grid cell."""
    from repro.core.engines import HostBatch
    from repro.core.sinks import BatchView
    from repro.runtime.prefetch import MarkerBatch

    nlp, r, t, maf, valid = arrays
    sub = nlp[lo:hi, t_lo:t_hi]
    out = {
        "nlp": sub,
        "r": r[lo:hi, t_lo:t_hi],
        "t": t[lo:hi, t_lo:t_hi],
        "maf": maf[lo:hi],
        "valid": valid[lo:hi],
        "batch_best_nlp": sub.max(axis=0),
        "batch_best_row": sub.argmax(axis=0).astype(np.int32),
        "hit_count": np.int32((sub >= threshold).sum()),
    }
    batch = MarkerBatch(index=index, lo=lo, hi=hi, source_id=0, local_lo=lo, local_hi=hi)
    return BatchView(
        HostBatch(batch, ()), out, t_hi - t_lo, t_lo=t_lo, block_index=block_index
    )


_grid_case = st.tuples(
    st.integers(0, 2**31 - 1),       # stream seed
    st.integers(8, 48),              # markers
    st.integers(4, 12),              # traits
    st.floats(0.0, 1.0),             # hit-threshold quantile
    st.lists(st.integers(1, 47), max_size=3, unique=True),   # marker cuts
    st.lists(st.integers(2, 11), max_size=2, unique=True),   # trait cuts
    st.integers(0, 2**31 - 1),       # cell-order permutation seed
)


@given(_grid_case)
@settings(max_examples=30, deadline=None)
def test_block_fold_order_never_changes_sink_results(case):
    seed, m, p, q, raw_cuts, raw_tcuts, perm_seed = case
    arrays = _sink_stream(seed, m, p)
    threshold = float(np.quantile(arrays[0], q))
    bounds = [0, *sorted({c for c in raw_cuts if c < m}), m]
    tbounds = [0, *sorted({c for c in raw_tcuts if c < p}), p]
    cells = [
        (i, lo, hi, k, t_lo, t_hi)
        for i, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:]))
        for k, (t_lo, t_hi) in enumerate(zip(tbounds[:-1], tbounds[1:]))
    ]

    results = []
    orders = [
        list(range(len(cells))),
        list(np.random.default_rng(perm_seed).permutation(len(cells))),
        list(reversed(range(len(cells)))),
    ]
    for order in orders:
        sinks = _make_sinks(m, p, threshold)
        for ci in order:
            i, lo, hi, k, t_lo, t_hi = cells[ci]
            view = _cell_view(arrays, lo, hi, t_lo, t_hi, i, k, threshold)
            pay: dict = {}
            for s in sinks:
                s.on_batch(view, pay)
        results.append(_results(sinks))

    ref = results[0]
    # the fold must also equal a plain single-cell (unblocked) pass —
    # except lambda_gc, whose probe is a per-marker-batch subsample by
    # design (same exclusion as the shard-fold properties above)
    single = _make_sinks(m, p, threshold)
    pay: dict = {}
    v = _cell_view(arrays, 0, m, 0, p, 0, 0, threshold)
    for s in single:
        s.on_batch(v, pay)
    rs = _results(single)

    for got, check_lambda in [(r, True) for r in results[1:]] + [(rs, False)]:
        np.testing.assert_array_equal(ref["best_nlp"], got["best_nlp"])
        np.testing.assert_array_equal(ref["best_marker"], got["best_marker"])
        oa, ob = np.lexsort(ref["hits"].T), np.lexsort(got["hits"].T)
        np.testing.assert_array_equal(ref["hits"][oa], got["hits"][ob])
        np.testing.assert_array_equal(ref["hit_stats"][oa], got["hit_stats"][ob])
        np.testing.assert_array_equal(ref["maf"], got["maf"])
        np.testing.assert_array_equal(ref["valid"], got["valid"])
        if check_lambda:
            assert ref["lambda_gc"] == got["lambda_gc"]


@given(st.integers(1, 6), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_correlation_bounded(m_markers, p_traits):
    rng = np.random.default_rng(m_markers * 31 + p_traits)
    n = 48
    g = rng.integers(0, 3, size=(m_markers, n)).astype(np.float32)
    y = rng.normal(size=(n, p_traits)).astype(np.float32)
    qb = covariate_basis(None, n)
    panel = residualize_and_standardize(jnp.asarray(y), qb)
    res, _ = A.assoc_batch(jnp.asarray(g), panel.y, n_samples=n, n_covariates=0)
    assert np.all(np.abs(np.asarray(res.r)) <= 1.0 + 1e-6)


# --------------------------- cell completion order (DESIGN.md §12)
#
# The multi-device executor completes grid cells in whatever order the
# fleet produces (work stealing, straggling devices, resume replay).  The
# invariant it leans on: ANY permutation of cell completion order yields
# byte-identical writer outputs and checkpoint-merge results.  Ties are
# planted deliberately — nlp drawn from a tiny discrete set makes exact
# cross-batch best-nlp ties common, exercising the BestTraitSink's
# order-normalized (nlp, lower-marker) fold.


def _executor_cells(seed, n_batches=3, n_blocks=3, m_per=16, p_width=4):
    """Synthetic committed-cell payloads for a (n_batches x n_blocks) grid."""
    rng = np.random.default_rng(seed)
    p = n_blocks * p_width
    cells = []
    for b in range(n_batches):
        lo, hi = b * m_per, (b + 1) * m_per
        nlp = rng.choice([0.0, 1.5, 2.5, 3.5], size=(m_per, p)).astype(np.float32)
        r = rng.normal(size=(m_per, p)).astype(np.float32)
        t = rng.normal(size=(m_per, p)).astype(np.float32)
        maf = rng.uniform(0.05, 0.5, m_per).astype(np.float32)
        for k in range(n_blocks):
            t_lo, t_hi = k * p_width, (k + 1) * p_width
            sub = nlp[:, t_lo:t_hi]
            rows, cols = np.nonzero(sub >= 2.0)
            shard = {
                "lo": np.asarray(lo), "hi": np.asarray(hi),
                "t_lo": np.asarray(t_lo), "t_hi": np.asarray(t_hi),
                "best_nlp": sub.max(axis=0).astype(np.float32),
                "best_row": sub.argmax(axis=0).astype(np.int32),
                "hits": np.stack(
                    [rows.astype(np.int32) + lo, cols.astype(np.int32) + t_lo], 1
                ),
                "hit_stats": np.stack(
                    [r[:, t_lo:t_hi][rows, cols], t[:, t_lo:t_hi][rows, cols],
                     sub[rows, cols]], 1
                ).astype(np.float32),
            }
            if t_lo == 0:
                shard["maf"] = maf
                shard["valid"] = np.ones(m_per, bool)
                shard["t_probe"] = t[: min(m_per, 64), 0].astype(np.float32)
            cells.append((b, k, shard))
    return cells, n_batches * m_per, p


class _StubSession:
    def __init__(self, n_markers, n_traits, n_batches, n_trait_blocks):
        self.n_markers = n_markers
        self.n_traits = n_traits
        self.n_batches = n_batches
        self.n_trait_blocks = n_trait_blocks
        self.multivariate = False
        self.marker_ids = None
        self.trait_names = None


def _write_cells(cells, order, stub, out_dir):
    from repro.api import TsvWriter
    from repro.api.session import CellResult

    w = TsvWriter(str(out_dir))
    w.open(stub)
    for i in order:
        b, k, shard = cells[i]
        w.write(CellResult.from_shard(b, k, dict(shard)))
    w.close()
    return {
        f: open(os.path.join(str(out_dir), f)).read()
        for f in ("hits.tsv", "per_trait_best.tsv", "qc.tsv")
    }


@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_cell_completion_order_never_changes_writer_output(seed, perm_seed):
    import tempfile

    cells, m, p = _executor_cells(seed)
    stub = _StubSession(m, p, 3, 3)
    d = tempfile.mkdtemp()
    ident = list(range(len(cells)))
    ref = _write_cells(cells, ident, stub, os.path.join(d, "ref"))
    perm = list(np.random.default_rng(perm_seed).permutation(len(cells)))
    assert _write_cells(cells, perm, stub, os.path.join(d, "perm")) == ref
    assert _write_cells(cells, ident[::-1], stub, os.path.join(d, "rev")) == ref


@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_cell_commit_order_never_changes_checkpoint_merge(seed, perm_seed):
    """Commit cells to the checkpoint in any order, merge offline through
    CheckpointReplay: identical writer outputs to the direct stream."""
    import tempfile

    from repro.api.session import CheckpointReplay
    from repro.api import TsvWriter
    from repro.runtime.checkpoint import ScanCheckpoint

    cells, m, p = _executor_cells(seed)
    stub = _StubSession(m, p, 3, 3)
    d = tempfile.mkdtemp()
    ref = _write_cells(cells, list(range(len(cells))), stub, os.path.join(d, "ref"))

    ck = ScanCheckpoint(
        os.path.join(d, "ck"), fingerprint="prop", n_batches=3, n_blocks=3
    )
    for i in np.random.default_rng(perm_seed).permutation(len(cells)):
        b, k, shard = cells[i]
        ck.commit_cell(b, k, shard)
    replay = CheckpointReplay(os.path.join(d, "ck"))
    out = os.path.join(d, "merged")
    replay.stream_to(TsvWriter(out))
    got = {
        f: open(os.path.join(out, f)).read()
        for f in ("hits.tsv", "per_trait_best.tsv", "qc.tsv")
    }
    assert got == ref


# ---------------------------------------------------------- sparse epilogue


_sparse_tiles = st.tuples(
    st.integers(0, 2**31 - 1),       # tile seed
    st.integers(4, 48),              # markers
    st.integers(2, 12),              # traits
    st.floats(1.0, 9.0),             # hit threshold (-log10 p)
    st.sampled_from([10.0, 240.0, 998.0, 4097.0, 21000.0]),
)


def _sparse_views(r, t, dof, thr, plan):
    """One synthetic cell twice: as a sparse-epilogue view (compacted
    device buffers) and as a dense-mode view under the same screen plan —
    exactly the two extraction paths the §13 contract says must agree."""
    from repro.core.engines import HostBatch
    from repro.core.sinks import BatchView
    from repro.runtime.prefetch import MarkerBatch

    m, p = t.shape
    sparse_out = {
        k: np.asarray(v)
        for k, v in A.sparse_epilogue_outputs(
            jnp.asarray(r), jnp.asarray(t), dof, plan
        ).items()
    }
    sparse_out["r"] = r
    sparse_out["t"] = t
    best_row = np.argmax(t * t, axis=0).astype(np.int32)
    dense_out = {
        "r": r,
        "t": t,
        "batch_best_row": best_row,
        "batch_best_t": t[best_row, np.arange(p)],
    }
    batch = MarkerBatch(index=0, lo=0, hi=m, source_id=0, local_lo=0, local_hi=m)
    kw = dict(dof=dof, t2_screen=plan.t2_screen)
    return (
        BatchView(HostBatch(batch, ()), sparse_out, p, **kw),
        BatchView(HostBatch(batch, ()), dense_out, p, **kw),
        sparse_out,
    )


@given(_sparse_tiles)
@settings(max_examples=20, deadline=None)
def test_sparse_screen_preserves_hits_argmax_ties(case):
    """Screening on t^2 + the canonical host-side refine preserves the hit
    set, the per-trait argmax, and nlp tie-breaks bitwise vs dense-mode
    extraction under the same plan — including the overflow fallback
    (DESIGN.md §13)."""
    from repro.core.sinks import extract_hits

    seed, m, p, thr, dof = case
    rng = np.random.default_rng(seed)
    r = np.clip(rng.normal(0, 0.25, (m, p)), -0.999, 0.999).astype(np.float32)
    # Inject exact +/- duplicates so the t^2 argmax tie-break is exercised.
    if m >= 6:
        r[1, 0], r[4, 0] = 0.5, -0.5
        r[2, -1], r[3, -1] = 0.25, 0.25
    t = np.asarray(S.t_from_r(jnp.asarray(r), dof))
    for capacity in (r.size, 1):  # roomy, and minimum (overflow when hot)
        plan = A.plan_sparse_epilogue(thr, dof, capacity=capacity)
        assert plan is not None
        sv, dv, out = _sparse_views(r, t, dof, thr, plan)
        assert "batch_best_nlp" not in out and "hit_nlp" not in out
        np.testing.assert_array_equal(
            out["batch_best_row"], np.argmax(t * t, axis=0)
        )
        np.testing.assert_array_equal(sv.best_nlp, dv.best_nlp)
        sh, ss = extract_hits(sv, thr)
        dh, ds = extract_hits(dv, thr)
        np.testing.assert_array_equal(sh, dh)
        np.testing.assert_array_equal(ss, ds)
        assert int(out["screen_count"]) >= len(sh)
        if len(sh):
            # the refined values stay within the CF's accuracy envelope of
            # the full-tile evaluation (bit-equality to the tile is NOT
            # promised — only sparse-vs-dense-mode equality is)
            tile = np.asarray(S.neglog10_p_from_t(jnp.asarray(t), dof))
            np.testing.assert_allclose(
                ss[:, 2], tile[sh[:, 0], sh[:, 1]], rtol=1e-4, atol=1e-4
            )
            np.testing.assert_array_equal(ss[:, 0], r[sh[:, 0], sh[:, 1]])
            np.testing.assert_array_equal(ss[:, 1], t[sh[:, 0], sh[:, 1]])
