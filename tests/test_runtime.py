"""Runtime substrate: prefetcher ordering/error propagation, work-stealing
queue, checkpoint atomicity/retention, kinship exclusion."""
import threading
import time

import numpy as np
import pytest

from repro.core import kinship as K
from repro.runtime.checkpoint import ScanCheckpoint, TrainCheckpoint, config_fingerprint
from repro.runtime.prefetch import Prefetcher
from repro.runtime.workqueue import WorkQueue


def test_prefetcher_preserves_order():
    def slow_square(i):
        time.sleep(0.002 * (7 - i % 7))  # deliberately out-of-order completion
        return i * i

    out = list(Prefetcher(range(40), slow_square, depth=4, num_workers=4))
    assert out == [i * i for i in range(40)]


def test_prefetcher_propagates_errors():
    def maybe_fail(i):
        if i == 5:
            raise RuntimeError("decode failed")
        return i

    it = iter(Prefetcher(range(10), maybe_fail, depth=2, num_workers=2))
    got = [next(it) for _ in range(5)]
    assert got == list(range(5))
    with pytest.raises(RuntimeError, match="decode failed"):
        next(it)


def test_prefetcher_window_bound():
    in_flight = []
    lock = threading.Lock()
    high_water = [0]

    def track(i):
        with lock:
            in_flight.append(i)
            high_water[0] = max(high_water[0], len(in_flight))
        time.sleep(0.002)
        with lock:
            in_flight.remove(i)
        return i

    consumed = []
    for x in Prefetcher(range(30), track, depth=3, num_workers=3):
        consumed.append(x)
        time.sleep(0.004)  # slow consumer: workers must not run ahead > depth
    assert consumed == list(range(30))
    assert high_water[0] <= 4  # depth + the one being yielded


def test_workqueue_steals_from_straggler():
    q = WorkQueue(64, lease_size=32)
    # fast worker drains its lease; slow worker holds a big lease
    a_first = q.claim("slow")
    assert a_first is not None
    done = []
    while True:
        idx = q.claim("fast")
        if idx is None:
            break
        q.complete("fast", idx)
        done.append(idx)
    stats = q.stats()
    assert stats["fast"].stolen_by > 0
    assert stats["slow"].stolen_from > 0
    # fast drains everything except slow's in-flight item and the one
    # unstealable last lease entry
    assert len(done) >= 62
    assert q.remaining() <= 2


def test_workqueue_stats_are_snapshots():
    """stats() must hand out copies: a caller mutating (or holding) the
    returned WorkerStats cannot corrupt the queue's live accounting."""
    q = WorkQueue(8, lease_size=2)
    idx = q.claim("w")
    snap = q.stats()
    snap["w"].claimed = 999
    snap["w"].completed = 999
    assert q.stats()["w"].claimed == 1
    assert q.stats()["w"].completed == 0
    q.complete("w", idx)
    assert snap["w"].completed == 999      # the snapshot stays a snapshot
    assert q.stats()["w"].completed == 1   # the live accounting moved on


def test_workqueue_victim_tie_break_deterministic():
    """Equal-length leases tie-break on the lexicographically greatest
    worker id — victim selection is a pure function of queue state."""
    for _ in range(3):  # no hidden dict-order dependence across instances
        q = WorkQueue(8, lease_size=4)
        q.claim("alpha")   # alpha and beta both hold 3-item leases
        q.claim("beta")
        assert q._pick_victim("thief") == "beta"
        # a strictly longer lease beats the name tie-break
        q2 = WorkQueue(12, lease_size=4)
        q2.claim("zz")
        q2.claim("aa")     # leases now equal (3, 3)
        q2.claim("aa")     # aa down to 2: zz is the longest
        assert q2._pick_victim("thief") == "zz"
        # workers with <= 1 item are never victims
        q3 = WorkQueue(2, lease_size=2)
        q3.claim("solo")
        assert q3._pick_victim("thief") is None


def test_workqueue_idle_polling_is_not_busy():
    """A polling worker with NOTHING in flight must not inflate busy_s:
    every interval is attributed exactly once, by the worker's outstanding
    count at the time — busy while it holds a claimed-uncompleted item
    (the pipelined look-ahead probes while computing), wait when it is
    empty-handed (idle spin on a drained queue)."""
    q = WorkQueue(1, lease_size=1)
    idx = q.claim("w")
    time.sleep(0.05)
    q.complete("w", idx)              # folds the real interval as busy
    base = q.stats()["w"].busy_s
    assert base >= 0.04
    for _ in range(5):
        time.sleep(0.01)
        assert q.claim("w") is None   # drained, empty-handed: wait, not busy
    st = q.stats()["w"]
    assert st.busy_s - base < 0.04    # the bug added ~50ms per poll
    assert st.wait_s >= 0.04          # the idle spin is accounted — as wait


def test_workqueue_polling_with_item_in_flight_is_busy():
    """The pipelined worker's shape: look-ahead claims issued WHILE a cell
    is in flight stay busy time — only empty-handed intervals are wait."""
    q = WorkQueue(1, lease_size=1)
    idx = q.claim("w")
    for _ in range(3):
        time.sleep(0.01)
        assert q.claim("w") is None   # look-ahead probe, item still in hand
    st = q.stats()["w"]
    assert st.busy_s >= 0.02
    assert st.wait_s < 0.005
    q.complete("w", idx)


def test_workqueue_set_lease_size():
    """Runtime retune affects future refills only; already-leased items
    keep their extent."""
    q = WorkQueue(10, lease_size=4)
    a0 = q.claim("a")                 # leases 4 (serves 1, holds 3)
    q.set_lease_size(1)
    b0 = q.claim("b")                 # fresh refill: leases exactly 1
    assert q._leases["b"] == []       # served its single item immediately
    assert len(q._leases["a"]) == 3   # a's fat lease is untouched
    assert q.lease_size == 1
    q.complete("a", a0)
    q.complete("b", b0)


def test_workqueue_stats_fold_in_flight_busy():
    """busy_s is monotone across snapshots taken DURING a long cell: the
    in-flight interval is folded into the returned copies (a claim-to-
    complete gap no longer reads as 0% utilization), without mutating the
    live accounting."""
    q = WorkQueue(4, lease_size=2)
    idx = q.claim("w")
    s1 = q.stats()["w"].busy_s
    time.sleep(0.03)
    s2 = q.stats()["w"].busy_s
    assert s2 >= s1 + 0.02            # mid-claim snapshots see the work
    time.sleep(0.03)
    s3 = q.stats()["w"].busy_s
    assert s3 >= s2 + 0.02            # and stay monotone
    q.complete("w", idx)
    done = q.stats()["w"].busy_s
    assert done >= s3 - 1e-6          # the fold was snapshot-only: no
    assert done < s3 + 1.0            # double count on complete


def test_workqueue_skip_completed():
    q = WorkQueue(10, lease_size=4, skip={0, 1, 2})
    seen = []
    while (i := q.claim("w")) is not None:
        seen.append(i)
        q.complete("w", i)
    assert sorted(seen) == list(range(3, 10))


def test_scan_checkpoint_atomic_and_idempotent(tmp_path):
    fp = config_fingerprint({"a": 1})
    ck = ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=4)
    ck.commit_batch(0, {"x": np.arange(3)})
    ck.commit_batch(2, {"x": np.arange(5)})
    assert ck.pending_batches() == [1, 3]
    # re-open: state survives
    ck2 = ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=4)
    assert ck2.pending_batches() == [1, 3]
    np.testing.assert_array_equal(ck2.load_batch(2)["x"], np.arange(5))
    # double commit is fine (work stealing can duplicate)
    ck2.commit_batch(2, {"x": np.arange(5)})
    assert ck2.pending_batches() == [1, 3]
    with pytest.raises(ValueError, match="different scan"):
        ScanCheckpoint(str(tmp_path), fingerprint="deadbeef", n_batches=4)
    with pytest.raises(ValueError, match="decomposition"):
        ScanCheckpoint(str(tmp_path), fingerprint=fp, n_batches=5)


def test_train_checkpoint_retention_and_restore(tmp_path):
    ck = TrainCheckpoint(str(tmp_path), keep_last=2)
    for step in [10, 20, 30]:
        ck.save(step, {"w": np.full(4, step)})
    assert ck.latest_step() == 30
    step, state = ck.restore()
    assert step == 30 and state["w"][0] == 30
    step, state = ck.restore(20)
    assert state["w"][0] == 20
    import os

    assert not os.path.isdir(os.path.join(str(tmp_path), "step_00000010"))


def test_kinship_exclusion_detects_planted_relatives():
    from repro.io import synth

    co = synth.make_cohort(
        n_samples=120, n_markers=3000, n_related_pairs=3, missing_rate=0.0, seed=11
    )
    keep, _, phi = K.exclude_related(co.dosages.T, co.sample_ids)
    for a, b in co.related_pairs:
        assert phi[a, b] > 0.15
        assert not (keep[a] and keep[b])
    # unrelated majority survives
    assert keep.sum() >= 120 - 3 - 6  # small slack for estimator noise
